//! Switched fabric: per-link egress queues over a pluggable topology.
//!
//! Pure state machine: the DES engine (`sim::cluster`) owns event scheduling
//! and asks the fabric what happens when a packet hits a queue. This keeps
//! the fabric unit-testable without an event loop.
//!
//! Since the leaf–spine rework the fabric owns one [`Port`] per
//! [`LinkId`] of its [`Topology`] — in single-switch mode that degenerates
//! to the seed model (one downlink queue per destination, `LinkId ==
//! NodeId`), while leaf–spine mode adds leaf→spine and spine→leaf egress
//! queues with ECMP/spray routing, per-hop ECN marking, accumulated INT
//! hints, per-port PFC, and link-level faults. See docs/TOPOLOGY.md.

use std::collections::VecDeque;

use crate::net::topo::{LinkDst, LinkId, SwitchCode, Topology, TopologyKind};
use crate::net::Packet;
use crate::sim::SimTime;
use crate::util::prng::Pcg64;
use crate::verbs::NodeId;

/// Fabric configuration. Defaults model the paper's CloudLab environment
/// (25 GbE ConnectX-5 behind a ToR).
#[derive(Clone, Debug)]
pub struct FabricCfg {
    pub nodes: usize,
    /// Link rate in Gbps (both uplink and downlink).
    pub link_gbps: f64,
    /// One-way propagation per hop (host↔switch), ns.
    pub prop_delay_ns: u64,
    /// Switch forwarding latency, ns.
    pub switch_delay_ns: u64,
    /// Per-output-port buffer capacity, bytes (shared-buffer slice).
    pub queue_cap_bytes: usize,
    /// RED/ECN marking thresholds, bytes.
    pub ecn_kmin: usize,
    pub ecn_kmax: usize,
    pub ecn_pmax: f64,
    /// PFC thresholds (only consulted when the transport requires PFC).
    pub pfc_xoff: usize,
    pub pfc_xon: usize,
    /// Probability a packet is corrupted/dropped in flight (link BER proxy).
    pub corrupt_prob: f64,
    /// Extra uniform delay applied to sprayed packets (multipath skew), ns.
    /// Single-switch stand-in only: leaf–spine fabrics produce real
    /// per-path skew from their per-hop queues, so this is ignored there.
    pub spray_jitter_ns: u64,
    /// Fabric shape: one ToR (seed model), a two-tier leaf–spine Clos,
    /// or a three-tier fat-tree (docs/SCALE.md).
    pub topo: TopologyKind,
    /// Core (non-edge) link rate in Gbps; `0` = same as `link_gbps`.
    pub core_gbps: f64,
    /// ECMP convergence delay: how long after a link failure routing
    /// still hashes flows onto the dead link (pre-convergence blackhole).
    pub reroute_ns: u64,
    /// Precomputed integer serialization rate in picoseconds per byte —
    /// the per-packet hot path of [`FabricCfg::serialize_ns`] (§Perf:
    /// one u64 multiply + div_ceil instead of an f64 mul/div/ceil per
    /// packet). `0` means "link rate does not divide 8000 ps evenly";
    /// the float formula is used instead. INVARIANT: must equal
    /// `ps_per_byte(link_gbps)` — change the rate only through
    /// [`FabricCfg::with_link_gbps`], which re-derives it; both stock
    /// environments (25 G, 100 G) have exact rates.
    pub ser_ps_per_byte: u64,
}

/// Exact integer picoseconds-per-byte for a link rate in Gbps, or `0`
/// when `8000 / rate` is not an integer (callers then keep f64 math).
/// `serialize_ns` is bit-identical between the two paths whenever this
/// returns non-zero: the exact value is `bytes·pspb/1000`, a rational
/// with denominator 1000, so the one f64 rounding (≤ half-ulp, < 1e-3
/// for any packet below a terabyte) can never move it across an integer
/// boundary — pinned by `serialize_integer_path_matches_float`.
pub fn ps_per_byte(link_gbps: f64) -> u64 {
    if !link_gbps.is_finite() || link_gbps <= 0.0 {
        return 0;
    }
    let pspb = 8000.0 / link_gbps;
    if pspb.fract() == 0.0 && pspb <= 1e9 && 8000.0 / pspb == link_gbps {
        pspb as u64
    } else {
        0
    }
}

/// Serialization time of `bytes` at `gbps`, with the integer fast path
/// when `pspb` (a cached `ps_per_byte(gbps)`) is exact.
fn serialize_at(bytes: usize, gbps: f64, pspb: u64) -> u64 {
    if pspb > 0 {
        (bytes as u64 * pspb).div_ceil(1000)
    } else {
        // Gbps = bits/ns; ns = bits / (bits/ns)
        ((bytes as f64 * 8.0) / gbps).ceil() as u64
    }
}

impl FabricCfg {
    /// 8-node CloudLab r7525-like environment: 25 GbE, shallow ToR buffers.
    pub fn cloudlab(nodes: usize) -> FabricCfg {
        FabricCfg {
            nodes,
            link_gbps: 25.0,
            prop_delay_ns: 1_000,
            switch_delay_ns: 500,
            queue_cap_bytes: 512 * 1024,
            ecn_kmin: 64 * 1024,
            ecn_kmax: 256 * 1024,
            ecn_pmax: 0.8,
            pfc_xoff: 384 * 1024,
            pfc_xon: 128 * 1024,
            corrupt_prob: 2e-5,
            spray_jitter_ns: 4_000,
            topo: TopologyKind::SingleSwitch,
            core_gbps: 0.0,
            reroute_ns: 50_000,
            ser_ps_per_byte: ps_per_byte(25.0),
        }
    }

    /// Hyperstack H100 environment: 100 G, deeper buffers, faster fabric.
    pub fn hyperstack(nodes: usize) -> FabricCfg {
        FabricCfg {
            nodes,
            link_gbps: 100.0,
            prop_delay_ns: 600,
            switch_delay_ns: 300,
            queue_cap_bytes: 2 * 1024 * 1024,
            ecn_kmin: 256 * 1024,
            ecn_kmax: 1024 * 1024,
            ecn_pmax: 0.8,
            pfc_xoff: 1536 * 1024,
            pfc_xon: 512 * 1024,
            corrupt_prob: 1e-5,
            spray_jitter_ns: 2_000,
            topo: TopologyKind::SingleSwitch,
            core_gbps: 0.0,
            reroute_ns: 50_000,
            ser_ps_per_byte: ps_per_byte(100.0),
        }
    }

    /// Reshape the fabric into a two-tier leaf–spine Clos (`nodes` must
    /// divide across `leaves`). Everything else — rates, buffers,
    /// thresholds — carries over per port.
    pub fn with_leaf_spine(mut self, leaves: usize, spines: usize) -> Self {
        self.topo = TopologyKind::LeafSpine { leaves, spines };
        // validate eagerly: a bad shape should fail at config time
        let _ = Topology::new(self.topo, self.nodes);
        self
    }

    /// Reshape the fabric into a three-tier fat-tree / multi-pod Clos
    /// (`nodes` must divide across `pods × leaves_per_pod` leaves). Same
    /// carry-over semantics as [`FabricCfg::with_leaf_spine`]; index math
    /// and routing in docs/SCALE.md.
    pub fn with_fat_tree(
        mut self,
        pods: usize,
        leaves_per_pod: usize,
        spines_per_pod: usize,
        core: usize,
    ) -> Self {
        self.topo = TopologyKind::FatTree {
            pods,
            leaves_per_pod,
            spines_per_pod,
            core,
        };
        // validate eagerly: a bad shape should fail at config time
        let _ = Topology::new(self.topo, self.nodes);
        self
    }

    /// Set the core (non-edge) link rate, Gbps.
    pub fn with_core_gbps(mut self, gbps: f64) -> Self {
        self.core_gbps = gbps;
        self
    }

    /// Change the edge link rate, keeping the precomputed integer
    /// serialization rate in sync (the two fields must never diverge —
    /// a stale `ser_ps_per_byte` would silently time every packet at
    /// the old rate).
    pub fn with_link_gbps(mut self, gbps: f64) -> Self {
        self.link_gbps = gbps;
        self.ser_ps_per_byte = ps_per_byte(gbps);
        self
    }

    /// Effective core link rate (falls back to the edge rate).
    pub fn core_gbps_eff(&self) -> f64 {
        if self.core_gbps > 0.0 {
            self.core_gbps
        } else {
            self.link_gbps
        }
    }

    /// The topology index map this config describes.
    pub fn topology(&self) -> Topology {
        Topology::new(self.topo, self.nodes)
    }

    /// Serialization time of `bytes` on an edge link, ns. Integer fast
    /// path when the rate divides 8000 ps/byte evenly (all stock
    /// environments); bit-identical to the float formula — see
    /// [`ps_per_byte`] and the parity test below.
    pub fn serialize_ns(&self, bytes: usize) -> u64 {
        serialize_at(bytes, self.link_gbps, self.ser_ps_per_byte)
    }

    /// Base RTT (no queueing) of the worst-case path: per-hop propagation
    /// plus switch traversals, both ways. Single-switch: 2 links + 1
    /// switch each way (the seed formula); leaf–spine: 4 links + 3
    /// switches; fat-tree (cross-pod): 6 links + 5 switches.
    pub fn base_rtt_ns(&self) -> u64 {
        let t = self.topology();
        2 * (t.path_links() as u64 * self.prop_delay_ns
            + t.path_switches() as u64 * self.switch_delay_ns)
    }

    /// Links a one-way worst-case path traverses (feeds `CcCtx::hops`).
    pub fn path_links(&self) -> u32 {
        self.topology().path_links()
    }

    /// Edge link bandwidth in bytes/ns.
    pub fn bytes_per_ns(&self) -> f64 {
        self.link_gbps / 8.0
    }

    /// The one marking-threshold triple both engine families consult:
    /// packet-mode RED marking (`Fabric::enqueue`) and the fluid engine's
    /// virtual-queue marks (`flowsim`) must mark at the same thresholds,
    /// or the CC signals the two fidelities feed would diverge by
    /// construction.
    pub fn marking(&self) -> MarkingProfile {
        MarkingProfile {
            kmin: self.ecn_kmin,
            kmax: self.ecn_kmax,
            pmax: self.ecn_pmax,
        }
    }
}

/// RED/ECN marking thresholds shared by the packet and fluid engines —
/// see [`FabricCfg::marking`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MarkingProfile {
    /// Depth (bytes) where marking begins.
    pub kmin: usize,
    /// Depth (bytes) where marking probability saturates at `pmax`.
    pub kmax: usize,
    /// Marking probability at `kmax` (packet-mode RED lottery; the fluid
    /// engine marks deterministically at `kmin` — its virtual queue is
    /// already a time-average, which is the smoothing the lottery exists
    /// to provide).
    pub pmax: f64,
}

/// What happened when a packet was offered to a queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnqueueOutcome {
    /// Queued; `ecn_marked` tells whether RED marked it at THIS hop.
    Queued { ecn_marked: bool },
    /// Tail-dropped: queue full, or the link is down (blackhole).
    Dropped,
}

/// One egress port: FIFO of packets with byte accounting plus link state
/// (fault + PFC) for the leaf–spine engine.
#[derive(Debug)]
pub struct Port {
    pub queue: VecDeque<Packet>,
    pub bytes: usize,
    /// Is the port currently serializing a packet?
    pub busy: bool,
    /// Link admin state: a down link blackholes everything offered to it.
    pub up: bool,
    /// Routing convergence mask: ECMP/spray skip this link (set
    /// `reroute_ns` after it went down, cleared on restore).
    pub routed_out: bool,
    /// Serialization-time multiplier (degraded-link fault; 1 = healthy).
    pub degrade: u32,
    /// Per-port PFC: this port has asserted XOFF toward its upstream
    /// (edge ports only — see docs/TOPOLOGY.md §PFC).
    pub pfc_asserted: bool,
    /// Cumulative bytes this port has transmitted — the busy-time proxy
    /// stamped into [`crate::net::NetHints`] for HPCC-style INT.
    pub tx_bytes: u64,
}

impl Default for Port {
    fn default() -> Port {
        Port {
            queue: VecDeque::new(),
            bytes: 0,
            busy: false,
            up: true,
            routed_out: false,
            degrade: 1,
            pfc_asserted: false,
            tx_bytes: 0,
        }
    }
}

/// The switched fabric: one [`Port`] per topology link. Single-switch
/// mode keeps the seed layout (downlink port per node — contention at the
/// destination downlink, the locus of incast, ECN, and PFC); leaf–spine
/// mode adds the core ports and multi-hop routing.
#[derive(Debug)]
pub struct Fabric {
    pub cfg: FabricCfg,
    pub topo: Topology,
    pub ports: Vec<Port>,
    /// Cached core-rate serialization constants (edge constants live in
    /// `cfg` — see `ser_ps_per_byte`).
    core_gbps: f64,
    core_pspb: u64,
    /// Edge/core link rates in Mbps, pre-rounded for `NetHints` stamping.
    edge_mbps: u32,
    core_mbps: u32,
    /// Statistics.
    pub drops_overflow: u64,
    pub drops_corrupt: u64,
    pub drops_link_down: u64,
    pub ecn_marks: u64,
    pub pfc_pauses: u64,
    pub forwarded: u64,
}

impl Fabric {
    pub fn new(mut cfg: FabricCfg) -> Fabric {
        // re-derive the cached integer serialization rate: the two cfg
        // fields are pub, and direct `cfg.link_gbps = …` mutation (the
        // established idiom for corrupt_prob etc.) must not leave a
        // stale rate timing every packet
        cfg.ser_ps_per_byte = ps_per_byte(cfg.link_gbps);
        let topo = cfg.topology();
        let ports = (0..topo.n_links()).map(|_| Port::default()).collect();
        let core_gbps = cfg.core_gbps_eff();
        Fabric {
            topo,
            ports,
            core_gbps,
            core_pspb: ps_per_byte(core_gbps),
            edge_mbps: (cfg.link_gbps * 1000.0).round() as u32,
            core_mbps: (core_gbps * 1000.0).round() as u32,
            cfg,
            drops_overflow: 0,
            drops_corrupt: 0,
            drops_link_down: 0,
            ecn_marks: 0,
            pfc_pauses: 0,
            forwarded: 0,
        }
    }

    // ---- routing ------------------------------------------------------------

    /// Next-hop egress link for a packet arriving at switch `sw`.
    /// Single-switch: the destination downlink. Leaf–spine: down toward
    /// the host when the destination hangs off this leaf, otherwise up to
    /// a spine — ECMP-hashed per flow, or chosen per packet for sprayed
    /// traffic (`rng` is consumed ONLY for sprayed up-hops, keeping RNG
    /// streams deterministic per event order). Fat-tree adds the third
    /// tier: a pod spine sends down when the destination pod is its own,
    /// else up to a core (tier-salted ECMP so the spine and core choices
    /// decorrelate); a core always sends down to one of the destination
    /// pod's spines.
    pub fn route(&self, sw: SwitchCode, pkt: &Packet, rng: &mut Pcg64) -> LinkId {
        match self.topo.kind {
            TopologyKind::SingleSwitch => self.topo.host_link(pkt.dst),
            TopologyKind::LeafSpine { leaves, .. } => {
                if (sw as usize) < leaves {
                    let leaf = sw as usize;
                    if self.topo.host_leaf(pkt.dst) == leaf {
                        self.topo.host_link(pkt.dst)
                    } else {
                        self.topo.up_link(leaf, self.pick_spine(leaf, pkt, rng))
                    }
                } else {
                    let spine = sw as usize - leaves;
                    self.topo.down_link(spine, self.topo.host_leaf(pkt.dst))
                }
            }
            TopologyKind::FatTree {
                leaves_per_pod,
                spines_per_pod,
                core,
                ..
            } => {
                let sw = sw as usize;
                let (leaves, spines) = (self.topo.n_leaves(), self.topo.n_spines());
                let dst_leaf = self.topo.host_leaf(pkt.dst);
                if sw < leaves {
                    // leaf: down to the host, or up to one of the pod's spines
                    if dst_leaf == sw {
                        self.topo.host_link(pkt.dst)
                    } else {
                        let first = self.topo.ft_up1(sw, 0);
                        self.pick_in_range(first, spines_per_pod, pkt, rng, 1)
                    }
                } else if sw < leaves + spines {
                    // pod spine: down into its own pod, or up to a core
                    let ps = sw - leaves;
                    if self.topo.spine_pod(ps) == self.topo.leaf_pod(dst_leaf) {
                        self.topo.ft_down1(ps, dst_leaf % leaves_per_pod)
                    } else {
                        let first = self.topo.ft_up2(ps, 0);
                        self.pick_in_range(first, core, pkt, rng, 2)
                    }
                } else {
                    // core: down to one of the destination pod's spines
                    let c = sw - leaves - spines;
                    let dst_pod = self.topo.leaf_pod(dst_leaf);
                    let first = self.topo.ft_down2(c, dst_pod * spines_per_pod);
                    self.pick_in_range(first, spines_per_pod, pkt, rng, 3)
                }
            }
        }
    }

    /// ECMP/spray choice over `n` consecutive candidate links starting at
    /// `first` (fat-tree link ranges are contiguous per hop). Same masking
    /// contract as [`Fabric::pick_spine`]: `routed_out` candidates are
    /// skipped; if every candidate is masked, fall back to the full set
    /// and let the packet blackhole — a partitioned fabric is partitioned.
    /// `tier` salts the ECMP hash so the per-level choices of one flow
    /// decorrelate ([`Topology::ecmp_hash_tier`]).
    fn pick_in_range(
        &self,
        first: LinkId,
        n: usize,
        pkt: &Packet,
        rng: &mut Pcg64,
        tier: u64,
    ) -> LinkId {
        let ok = |i: usize| !self.ports[first + i].routed_out;
        let n_ok = (0..n).filter(|&i| ok(i)).count();
        let from_ok = n_ok > 0;
        let m = if from_ok { n_ok } else { n };
        let idx = if pkt.spray {
            rng.index(m)
        } else {
            (Topology::ecmp_hash_tier(pkt.src, pkt.dst, Topology::flow_label(pkt), tier)
                % m as u64) as usize
        };
        if !from_ok {
            return first + idx;
        }
        // idx-th unmasked candidate
        let mut k = idx;
        for i in 0..n {
            if ok(i) {
                if k == 0 {
                    return first + i;
                }
                k -= 1;
            }
        }
        unreachable!("idx < n_ok")
    }

    /// Spine choice at a leaf: candidates are up-links not masked out by
    /// routing convergence (`routed_out`); if every spine is masked, fall
    /// back to the full set — the packet will blackhole at the dead port,
    /// which is exactly what a partitioned fabric does.
    fn pick_spine(&self, leaf: usize, pkt: &Packet, rng: &mut Pcg64) -> usize {
        let TopologyKind::LeafSpine { spines, .. } = self.topo.kind else {
            unreachable!();
        };
        let ok = |s: usize| !self.ports[self.topo.up_link(leaf, s)].routed_out;
        let n_ok = (0..spines).filter(|&s| ok(s)).count();
        let from_ok = n_ok > 0;
        let n = if from_ok { n_ok } else { spines };
        let idx = if pkt.spray {
            // true per-packet spraying (OptiNIC/UCCL/Falcon): every
            // fragment may take a different spine
            rng.index(n)
        } else {
            (Topology::ecmp_hash(pkt.src, pkt.dst, Topology::flow_label(pkt)) % n as u64)
                as usize
        };
        if !from_ok {
            return idx;
        }
        // idx-th unmasked spine
        let mut k = idx;
        for s in 0..spines {
            if ok(s) {
                if k == 0 {
                    return s;
                }
                k -= 1;
            }
        }
        unreachable!("idx < n_ok")
    }

    // ---- queueing -----------------------------------------------------------

    /// Offer a packet to egress link `link`.
    pub fn enqueue(&mut self, link: LinkId, mut pkt: Packet, rng: &mut Pcg64) -> EnqueueOutcome {
        let MarkingProfile { kmin, kmax, pmax } = self.cfg.marking();
        let cap = self.cfg.queue_cap_bytes;
        let port = &mut self.ports[link];
        if !port.up {
            // blackhole: a dead link drops everything offered to it
            self.drops_link_down += 1;
            return EnqueueOutcome::Dropped;
        }
        if port.bytes + pkt.size > cap {
            self.drops_overflow += 1;
            return EnqueueOutcome::Dropped;
        }
        // RED/ECN marking on data packets only (control stays unmarked).
        // The probability is computed on the POST-enqueue depth — the
        // queue including this packet — so a packet that itself pushes
        // the queue past kmin/kmax cannot escape marking (the pre-push
        // depth let exactly the queue-filling packets through unmarked).
        // A CE mark from an earlier hop sticks; no second lottery.
        let mut marked = false;
        if pkt.is_data() && !pkt.ecn {
            let q = port.bytes + pkt.size;
            if q > kmin {
                let p = if q >= kmax {
                    1.0
                } else {
                    pmax * (q - kmin) as f64 / (kmax - kmin) as f64
                };
                if rng.chance(p) {
                    pkt.ecn = true;
                    marked = true;
                    self.ecn_marks += 1;
                }
            }
        }
        port.bytes += pkt.size;
        port.queue.push_back(pkt);
        EnqueueOutcome::Queued { ecn_marked: marked }
    }

    /// Pop the head-of-line packet from a link (the engine calls this when
    /// the link finishes serializing the previous packet).
    pub fn dequeue(&mut self, link: LinkId) -> Option<Packet> {
        let port = &mut self.ports[link];
        let pkt = port.queue.pop_front()?;
        port.bytes -= pkt.size;
        port.tx_bytes += pkt.size as u64;
        self.forwarded += 1;
        Some(pkt)
    }

    /// Stamp/accumulate the uniform telemetry header on a data packet at
    /// port dequeue. This is the ONE code path every CC signal source
    /// derives from — DCQCN marks, HPCC INT, and EQDS edge-queue backoff
    /// all read the same `NetHints` (§3.1.3 decoupling: CC feedback is
    /// stamped, not synthesized per algorithm).
    ///
    /// Multi-hop accumulation: the hop with the longest queue DRAIN TIME
    /// (`qdepth / link_mbps`, compared by integer cross-multiply) seen so
    /// far defines the bottleneck — its depth, busy-time counter, and
    /// link rate ride together; CE marks OR in; `hops` counts stamping
    /// hops. Raw depth comparison was the ≤2-hop shortcut: with a third
    /// tier running at a different rate, a short queue on a slow link can
    /// be the true bottleneck while a deeper queue on a 4× faster core
    /// link drains first — HPCC/Swift must see the slow one
    /// (`stamping_bottleneck_is_drain_time_not_raw_depth` pins the case
    /// the old rule got wrong). Rates equal ⇒ reduces exactly to the
    /// depth comparison; one hop (single-switch) ⇒ the seed stamping.
    pub fn stamp_hints(pkt: &mut Packet, qdepth: usize, tx_bytes: u64, link_mbps: u32) {
        let ecn = pkt.ecn;
        if let crate::net::PktKind::Data(h) = &mut pkt.kind {
            let hints = &mut h.hints;
            let q = qdepth.min(u32::MAX as usize) as u32;
            let deeper = if hints.link_mbps == 0 || link_mbps == 0 {
                q >= hints.qdepth // unrated hint: depth is all we have
            } else {
                q as u64 * hints.link_mbps as u64 >= hints.qdepth as u64 * link_mbps as u64
            };
            if hints.hops == 0 || deeper {
                hints.qdepth = q;
                // the bottleneck's OWN counter rides with its depth and
                // rate — mixing another hop's (larger) counter with this
                // hop's link rate would skew HPCC's txRate/B utilization
                // term; a bottleneck migration between ACKs just yields
                // one zero Δ sample (HPCC guards non-monotone counters)
                hints.tx_bytes = tx_bytes;
                hints.link_mbps = link_mbps;
            }
            hints.ecn |= ecn;
            hints.hops = hints.hops.saturating_add(1);
        }
    }

    /// The stamping rate for a link, Mbps (edge vs core).
    pub fn link_mbps(&self, link: LinkId) -> u32 {
        if self.topo.is_edge(link) {
            self.edge_mbps
        } else {
            self.core_mbps
        }
    }

    pub fn queue_bytes(&self, link: LinkId) -> usize {
        self.ports[link].bytes
    }

    // ---- PFC ----------------------------------------------------------------

    /// Per-port PFC: should THIS link assert a pause toward its upstream
    /// right now? (Consulted only when the sending transport requires
    /// lossless operation, i.e. RoCE, and only for edge ports — the
    /// incast locus.) One hot port pausing every sender in the cluster
    /// was the head-of-line amplification bug this replaced.
    pub fn pfc_should_pause(&self, link: LinkId) -> bool {
        self.ports[link].bytes >= self.cfg.pfc_xoff
    }

    pub fn pfc_should_resume(&self, link: LinkId) -> bool {
        self.ports[link].bytes <= self.cfg.pfc_xon
    }

    // ---- faults -------------------------------------------------------------

    /// Take a link down: flush its queue (counted as link-down drops) and
    /// blackhole everything offered until [`Fabric::link_up`]. Returns
    /// the number of packets flushed.
    pub fn link_down(&mut self, link: LinkId) -> usize {
        let port = &mut self.ports[link];
        port.up = false;
        let n = port.queue.len();
        port.queue.clear();
        port.bytes = 0;
        self.drops_link_down += n as u64;
        n
    }

    /// Restore a downed link and clear its routing mask.
    pub fn link_up(&mut self, link: LinkId) {
        let port = &mut self.ports[link];
        port.up = true;
        port.routed_out = false;
    }

    /// Routing convergence caught up: mask a still-down link out of
    /// ECMP/spray choice. No-op if the link already recovered.
    pub fn reroute_out(&mut self, link: LinkId) {
        if !self.ports[link].up {
            self.ports[link].routed_out = true;
        }
    }

    /// Degraded-link fault: multiply serialization time by `factor`.
    pub fn degrade_link(&mut self, link: LinkId, factor: u32) {
        self.ports[link].degrade = factor.max(1);
    }

    // ---- timing / loss ------------------------------------------------------

    /// In-flight corruption lottery (applies per packet on the final
    /// switch→host leg only, in every topology — so `corrupt_prob` means
    /// the same end-to-end loss rate regardless of hop count). Control-
    /// plane packets are assumed protected (FEC + retry in the reliable
    /// channel), data/ack are subject to loss.
    pub fn corrupted(&mut self, pkt: &Packet, rng: &mut Pcg64) -> bool {
        if matches!(
            pkt.kind,
            crate::net::PktKind::Ctrl(_)
                | crate::net::PktKind::Pause { .. }
                // EQDS credits ride the protected control class; losing a
                // grant would stall a sender until its WQE deadline
                | crate::net::PktKind::Credit { .. }
                | crate::net::PktKind::PullReq { .. }
        ) {
            return false;
        }
        if rng.chance(self.cfg.corrupt_prob) {
            self.drops_corrupt += 1;
            true
        } else {
            false
        }
    }

    /// Extra delay for sprayed packets — the single-switch multipath
    /// stand-in. Leaf–spine fabrics return 0: their skew is real (each
    /// spine path has its own queues), so adding jitter on top would
    /// double-count it.
    pub fn spray_delay(&self, pkt: &Packet, rng: &mut Pcg64) -> u64 {
        if pkt.spray && self.cfg.spray_jitter_ns > 0 && !self.topo.kind.is_multitier() {
            rng.below(self.cfg.spray_jitter_ns)
        } else {
            0
        }
    }

    /// Time for a switch to forward + serialize a packet onto `link`
    /// (core links may run at a different rate; degraded links stretch).
    pub fn port_tx_ns(&self, link: LinkId, pkt: &Packet) -> SimTime {
        let ser = if self.topo.is_edge(link) {
            self.cfg.serialize_ns(pkt.size)
        } else {
            serialize_at(pkt.size, self.core_gbps, self.core_pspb)
        };
        self.cfg.switch_delay_ns + ser * self.ports[link].degrade as u64
    }

    /// Where egress link `link` delivers (host vs next switch).
    pub fn link_dst(&self, link: LinkId) -> LinkDst {
        self.topo.link_dst(link)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::DataHdr;
    use crate::verbs::MrId;

    fn data_pkt(dst: NodeId, len: usize) -> Packet {
        Packet::data(
            0,
            dst,
            DataHdr {
                dst_qpn: 0,
                src_qpn: 0,
                psn: 0,
                wqe_seq: 0,
                msg_offset: 0,
                len,
                last: false,
                msg_len: len,
                src_mr: MrId(0),
                src_off: 0,
                reth: None,
                stride: 1,
                imm: None,
                deadline: None,
                tx_time: 0,
                hints: crate::net::NetHints::default(),
            },
        )
    }

    fn small_cfg() -> FabricCfg {
        FabricCfg {
            nodes: 2,
            link_gbps: 10.0,
            prop_delay_ns: 100,
            switch_delay_ns: 50,
            queue_cap_bytes: 3000,
            ecn_kmin: 1000,
            ecn_kmax: 2000,
            ecn_pmax: 1.0,
            pfc_xoff: 2500,
            pfc_xon: 500,
            corrupt_prob: 0.0,
            spray_jitter_ns: 0,
            topo: TopologyKind::SingleSwitch,
            core_gbps: 0.0,
            reroute_ns: 10_000,
            ser_ps_per_byte: ps_per_byte(10.0),
        }
    }

    fn leaf_spine_cfg() -> FabricCfg {
        let mut cfg = small_cfg();
        cfg.nodes = 4;
        cfg.topo = TopologyKind::LeafSpine {
            leaves: 2,
            spines: 2,
        };
        cfg
    }

    #[test]
    fn serialize_time() {
        let cfg = small_cfg();
        // 1000 bytes at 10 Gbps = 8000 bits / 10 bits-per-ns = 800 ns
        assert_eq!(cfg.serialize_ns(1000), 800);
    }

    #[test]
    fn ps_per_byte_exact_rates_only() {
        assert_eq!(ps_per_byte(25.0), 320);
        assert_eq!(ps_per_byte(100.0), 80);
        assert_eq!(ps_per_byte(10.0), 800);
        assert_eq!(ps_per_byte(12.5), 640);
        // 8000/7 is not an integer → float fallback
        assert_eq!(ps_per_byte(7.0), 0);
        assert_eq!(ps_per_byte(0.0), 0);
        assert_eq!(ps_per_byte(-1.0), 0);
        assert_eq!(ps_per_byte(f64::NAN), 0);
    }

    /// The satellite contract: the integer picosecond path must be
    /// bit-identical to the float formula across the full packet-size
    /// range for both stock environments (and the 10 G test fabric).
    #[test]
    fn serialize_integer_path_matches_float() {
        for cfg in [
            FabricCfg::cloudlab(8),
            FabricCfg::hyperstack(8),
            small_cfg(),
        ] {
            assert!(cfg.ser_ps_per_byte > 0, "{} Gbps should be exact", cfg.link_gbps);
            let float_ns =
                |bytes: usize| ((bytes as f64 * 8.0) / cfg.link_gbps).ceil() as u64;
            // every size up to jumbo-frame territory…
            for bytes in 0..=16384usize {
                assert_eq!(
                    cfg.serialize_ns(bytes),
                    float_ns(bytes),
                    "{} Gbps @ {bytes} B",
                    cfg.link_gbps
                );
            }
            // …plus train-scale and pathological sizes
            for bytes in [1 << 20, (1 << 20) + 1, 123_456_789, 1 << 33] {
                assert_eq!(cfg.serialize_ns(bytes), float_ns(bytes));
            }
        }
    }

    #[test]
    fn construction_heals_stale_cached_rate() {
        // direct field mutation (the corrupt_prob idiom) leaves the
        // cached integer rate stale; Fabric::new must re-derive it
        let mut cfg = FabricCfg::cloudlab(2);
        cfg.link_gbps = 100.0;
        assert_eq!(cfg.ser_ps_per_byte, 320); // stale
        let f = Fabric::new(cfg);
        assert_eq!(f.cfg.ser_ps_per_byte, 80); // healed
        assert_eq!(f.cfg.serialize_ns(1000), 80);
    }

    #[test]
    fn serialize_float_fallback_when_inexact() {
        let cfg = small_cfg().with_link_gbps(7.0);
        assert_eq!(cfg.ser_ps_per_byte, 0);
        // 1000 B at 7 Gbps = 8000/7 ns = 1142.86 → ceil 1143
        assert_eq!(cfg.serialize_ns(1000), 1143);
        // the setter keeps the integer rate in sync both directions
        assert_eq!(cfg.with_link_gbps(10.0).ser_ps_per_byte, 800);
    }

    #[test]
    fn fifo_order_and_accounting() {
        let mut f = Fabric::new(small_cfg());
        let mut rng = Pcg64::seeded(1);
        assert!(matches!(
            f.enqueue(1, data_pkt(1, 100), &mut rng),
            EnqueueOutcome::Queued { .. }
        ));
        assert!(matches!(
            f.enqueue(1, data_pkt(1, 200), &mut rng),
            EnqueueOutcome::Queued { .. }
        ));
        let q0 = f.queue_bytes(1);
        assert!(q0 > 300); // includes headers
        let p1 = f.dequeue(1).unwrap();
        let p2 = f.dequeue(1).unwrap();
        assert!(p1.size < p2.size); // FIFO: 100-byte first
        assert_eq!(f.queue_bytes(1), 0);
        assert!(f.dequeue(1).is_none());
    }

    #[test]
    fn tail_drop_on_overflow() {
        let mut f = Fabric::new(small_cfg());
        let mut rng = Pcg64::seeded(2);
        let mut dropped = false;
        for _ in 0..10 {
            if f.enqueue(1, data_pkt(1, 1000), &mut rng) == EnqueueOutcome::Dropped {
                dropped = true;
                break;
            }
        }
        assert!(dropped);
        assert!(f.drops_overflow >= 1);
        assert!(f.queue_bytes(1) <= 3000);
    }

    #[test]
    fn ecn_marks_above_kmin() {
        let mut f = Fabric::new(small_cfg());
        let mut rng = Pcg64::seeded(3);
        // two 1 KB packets put the POST-enqueue depth of the second past
        // kmax ⇒ it is marked with probability 1
        let _ = f.enqueue(1, data_pkt(1, 1000), &mut rng);
        match f.enqueue(1, data_pkt(1, 1000), &mut rng) {
            EnqueueOutcome::Queued { ecn_marked } => assert!(ecn_marked),
            other => panic!("{other:?}"),
        }
        assert!(f.ecn_marks >= 1);
    }

    /// Satellite regression (fails pre-fix): marking used the queue depth
    /// BEFORE the arriving packet was added, so a packet that itself
    /// filled the queue past kmin/kmax escaped marking — into an empty
    /// queue, a single kmax-crossing packet came out clean, and DCQCN
    /// never saw the congestion it caused.
    #[test]
    fn ecn_marks_on_post_enqueue_depth() {
        let mut f = Fabric::new(small_cfg());
        let mut rng = Pcg64::seeded(7);
        // 2500 B payload > kmax = 2000 on an EMPTY queue: post-enqueue
        // depth ≥ kmax ⇒ marking probability 1, pre-fix probability 0
        match f.enqueue(1, data_pkt(1, 2500), &mut rng) {
            EnqueueOutcome::Queued { ecn_marked } => {
                assert!(ecn_marked, "queue-filling packet must be marked")
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(f.ecn_marks, 1);
    }

    #[test]
    fn already_marked_packets_skip_the_lottery() {
        let mut f = Fabric::new(leaf_spine_cfg());
        let mut rng = Pcg64::seeded(8);
        let mut pkt = data_pkt(2, 2500);
        pkt.ecn = true; // marked at an earlier hop
        let marks_before = f.ecn_marks;
        match f.enqueue(2, pkt, &mut rng) {
            EnqueueOutcome::Queued { ecn_marked } => assert!(!ecn_marked),
            other => panic!("{other:?}"),
        }
        assert_eq!(f.ecn_marks, marks_before, "no double-count of CE marks");
        assert!(f.dequeue(2).unwrap().ecn, "the mark itself sticks");
    }

    /// Satellite regression (fails pre-fix): PFC decisions were global —
    /// `any` port above XOFF paused EVERY sender, `all` ports below XON
    /// gated every resume. Per-port: a hot port's state is invisible to
    /// an idle port's.
    #[test]
    fn pfc_thresholds_are_per_port() {
        let mut f = Fabric::new(small_cfg());
        let mut rng = Pcg64::seeded(4);
        assert!(!f.pfc_should_pause(1));
        let _ = f.enqueue(1, data_pkt(1, 1400), &mut rng);
        let _ = f.enqueue(1, data_pkt(1, 1400), &mut rng);
        // port 1 is hot…
        assert!(f.pfc_should_pause(1));
        assert!(!f.pfc_should_resume(1));
        // …and port 0, untouched, must neither pause nor block resume
        assert!(!f.pfc_should_pause(0), "idle port paused by a hot one");
        assert!(f.pfc_should_resume(0));
        let _ = f.dequeue(1);
        let _ = f.dequeue(1);
        assert!(f.pfc_should_resume(1));
    }

    #[test]
    fn corruption_respects_kind() {
        let mut cfg = small_cfg();
        cfg.corrupt_prob = 1.0;
        let mut f = Fabric::new(cfg);
        let mut rng = Pcg64::seeded(5);
        assert!(f.corrupted(&data_pkt(1, 10), &mut rng));
        let ctrl = Packet::ctrl(
            0,
            1,
            crate::net::CtrlMsg {
                tag: 0,
                payload: vec![],
            },
        );
        assert!(!f.corrupted(&ctrl, &mut rng));
    }

    #[test]
    fn dequeue_accumulates_tx_bytes_and_stamping_reads_them() {
        let mut f = Fabric::new(small_cfg());
        let mut rng = Pcg64::seeded(6);
        let _ = f.enqueue(1, data_pkt(1, 100), &mut rng);
        let _ = f.enqueue(1, data_pkt(1, 200), &mut rng);
        let qlen = f.queue_bytes(1);
        let mut p1 = f.dequeue(1).unwrap();
        let tx1 = f.ports[1].tx_bytes;
        assert_eq!(tx1, p1.size as u64);
        Fabric::stamp_hints(&mut p1, qlen, tx1, f.link_mbps(1));
        let h = p1.data_hdr().unwrap().hints;
        assert_eq!(h.qdepth as usize, qlen);
        assert_eq!(h.tx_bytes, tx1);
        assert_eq!(h.link_mbps, 10_000); // 10 Gbps edge
        assert_eq!(h.hops, 1);
        assert!(!h.ecn);
        let p2 = f.dequeue(1).unwrap();
        assert_eq!(f.ports[1].tx_bytes, (p1.size + p2.size) as u64);
    }

    #[test]
    fn stamping_accumulates_bottleneck_across_hops() {
        let mut pkt = data_pkt(1, 100);
        // hop 1: shallow queue on a fast core link
        Fabric::stamp_hints(&mut pkt, 500, 10_000, 100_000);
        // hop 2: the bottleneck — deepest queue wins and carries its
        // OWN tx counter and link rate (never another hop's counter
        // paired with this hop's rate — that would corrupt HPCC's
        // utilization arithmetic)
        Fabric::stamp_hints(&mut pkt, 9_000, 4_000, 25_000);
        // hop 3: shallower again — bottleneck fields stay put
        Fabric::stamp_hints(&mut pkt, 100, 90_000, 25_000);
        let h = pkt.data_hdr().unwrap().hints;
        assert_eq!(h.qdepth, 9_000);
        assert_eq!(h.link_mbps, 25_000);
        assert_eq!(h.tx_bytes, 4_000);
        assert_eq!(h.hops, 3);
    }

    // ---- leaf–spine routing -------------------------------------------------

    #[test]
    fn routes_down_on_same_leaf_and_through_spines_across() {
        let f = Fabric::new(leaf_spine_cfg());
        let mut rng = Pcg64::seeded(9);
        // 0 → 1 share leaf 0: straight to the host link
        assert_eq!(f.route(f.topo.sw_leaf(0), &data_pkt(1, 10), &mut rng), 1);
        // 0 → 2 crosses leaves: leaf 0 picks an up-link
        let up = f.route(f.topo.sw_leaf(0), &data_pkt(2, 10), &mut rng);
        let LinkDst::Spine(s) = f.link_dst(up) else {
            panic!("cross-leaf first hop must go up, got {:?}", f.link_dst(up));
        };
        assert_eq!(up, f.topo.up_link(0, s));
        // at the spine: down toward leaf 1
        let down = f.route(f.topo.sw_spine(s), &data_pkt(2, 10), &mut rng);
        assert_eq!(down, f.topo.down_link(s, 1));
        assert_eq!(f.link_dst(down), LinkDst::Leaf(1));
        // at leaf 1: the destination host link
        assert_eq!(f.route(f.topo.sw_leaf(1), &data_pkt(2, 10), &mut rng), 2);
    }

    #[test]
    fn ecmp_pins_a_flow_spray_spreads_packets() {
        let f = Fabric::new(leaf_spine_cfg());
        let mut rng = Pcg64::seeded(10);
        // ECMP: same flow, same spine, every time
        let first = f.route(f.topo.sw_leaf(0), &data_pkt(3, 10), &mut rng);
        for _ in 0..16 {
            assert_eq!(f.route(f.topo.sw_leaf(0), &data_pkt(3, 10), &mut rng), first);
        }
        // spray: both spines see traffic
        let mut sprayed = data_pkt(3, 10);
        sprayed.spray = true;
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..64 {
            seen.insert(f.route(f.topo.sw_leaf(0), &sprayed, &mut rng));
        }
        assert_eq!(seen.len(), 2, "spray must use every spine");
    }

    #[test]
    fn reroute_masks_dead_spines_until_restore() {
        let mut f = Fabric::new(leaf_spine_cfg());
        let mut rng = Pcg64::seeded(11);
        let up0 = f.topo.up_link(0, 0);
        f.link_down(up0);
        // pre-convergence: ECMP may still pick the dead up-link
        // (blackhole window); post-convergence it never does
        f.reroute_out(up0);
        let mut sprayed = data_pkt(3, 10);
        sprayed.spray = true;
        for _ in 0..64 {
            assert_eq!(
                f.route(f.topo.sw_leaf(0), &sprayed, &mut rng),
                f.topo.up_link(0, 1),
                "masked spine must not be chosen"
            );
        }
        // restore clears the mask
        f.link_up(up0);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..64 {
            seen.insert(f.route(f.topo.sw_leaf(0), &sprayed, &mut rng));
        }
        assert_eq!(seen.len(), 2);
    }

    // ---- fat-tree routing ---------------------------------------------------

    fn fat_tree_cfg() -> FabricCfg {
        let mut cfg = small_cfg();
        cfg.nodes = 16; // 2 pods × 2 leaves × 4 hosts
        cfg.with_fat_tree(2, 2, 2, 2)
    }

    #[test]
    fn fat_tree_routes_within_pod_and_across() {
        let f = Fabric::new(fat_tree_cfg());
        let mut rng = Pcg64::seeded(20);
        // host 0 and 1 share leaf 0: straight to the host link
        assert_eq!(f.route(f.topo.sw_leaf(0), &data_pkt(1, 10), &mut rng), 1);
        // 0 → 5 crosses leaves inside pod 0: leaf → pod spine → leaf
        let up = f.route(f.topo.sw_leaf(0), &data_pkt(5, 10), &mut rng);
        let LinkDst::Spine(ps) = f.link_dst(up) else {
            panic!("cross-leaf first hop must go up, got {:?}", f.link_dst(up));
        };
        assert_eq!(f.topo.spine_pod(ps), 0, "same-pod traffic stays in pod");
        let down = f.route(f.topo.sw_spine(ps), &data_pkt(5, 10), &mut rng);
        assert_eq!(down, f.topo.ft_down1(ps, 1));
        assert_eq!(f.link_dst(down), LinkDst::Leaf(1));
        assert_eq!(f.route(f.topo.sw_leaf(1), &data_pkt(5, 10), &mut rng), 5);
        // 0 → 9 crosses pods: leaf → pod spine → core → pod spine → leaf
        let up1 = f.route(f.topo.sw_leaf(0), &data_pkt(9, 10), &mut rng);
        let LinkDst::Spine(ps1) = f.link_dst(up1) else {
            panic!("expected up1");
        };
        let up2 = f.route(f.topo.sw_spine(ps1), &data_pkt(9, 10), &mut rng);
        let LinkDst::Core(c) = f.link_dst(up2) else {
            panic!("cross-pod traffic must climb to a core, got {:?}", f.link_dst(up2));
        };
        let down2 = f.route(f.topo.sw_core(c), &data_pkt(9, 10), &mut rng);
        let LinkDst::Spine(ps2) = f.link_dst(down2) else {
            panic!("expected down2");
        };
        assert_eq!(f.topo.spine_pod(ps2), 1, "core must descend into the dst pod");
        let down1 = f.route(f.topo.sw_spine(ps2), &data_pkt(9, 10), &mut rng);
        assert_eq!(down1, f.topo.ft_down1(ps2, 0));
        assert_eq!(f.link_dst(down1), LinkDst::Leaf(2));
        assert_eq!(f.route(f.topo.sw_leaf(2), &data_pkt(9, 10), &mut rng), 9);
    }

    #[test]
    fn fat_tree_ecmp_pins_spray_spreads_every_tier() {
        let f = Fabric::new(fat_tree_cfg());
        let mut rng = Pcg64::seeded(21);
        // ECMP: one flow, one choice, at every up tier
        let up1 = f.route(f.topo.sw_leaf(0), &data_pkt(9, 10), &mut rng);
        let LinkDst::Spine(ps) = f.link_dst(up1) else { panic!() };
        let up2 = f.route(f.topo.sw_spine(ps), &data_pkt(9, 10), &mut rng);
        let down2 = f.route(f.topo.sw_core(0), &data_pkt(9, 10), &mut rng);
        for _ in 0..16 {
            assert_eq!(f.route(f.topo.sw_leaf(0), &data_pkt(9, 10), &mut rng), up1);
            assert_eq!(f.route(f.topo.sw_spine(ps), &data_pkt(9, 10), &mut rng), up2);
            assert_eq!(f.route(f.topo.sw_core(0), &data_pkt(9, 10), &mut rng), down2);
        }
        // spray: every candidate at every up tier sees traffic
        let mut sprayed = data_pkt(9, 10);
        sprayed.spray = true;
        let spread = |sw: SwitchCode, rng: &mut Pcg64| {
            let mut seen = std::collections::BTreeSet::new();
            for _ in 0..64 {
                seen.insert(f.route(sw, &sprayed, rng));
            }
            seen.len()
        };
        assert_eq!(spread(f.topo.sw_leaf(0), &mut rng), 2, "2 pod spines");
        assert_eq!(spread(f.topo.sw_spine(0), &mut rng), 2, "2 cores");
        assert_eq!(spread(f.topo.sw_core(0), &mut rng), 2, "2 dst-pod spines");
    }

    #[test]
    fn fat_tree_reroute_masks_dead_uplinks() {
        let mut f = Fabric::new(fat_tree_cfg());
        let mut rng = Pcg64::seeded(22);
        let up = f.topo.ft_up1(0, 0);
        f.link_down(up);
        f.reroute_out(up);
        let mut sprayed = data_pkt(9, 10);
        sprayed.spray = true;
        for _ in 0..64 {
            assert_eq!(
                f.route(f.topo.sw_leaf(0), &sprayed, &mut rng),
                f.topo.ft_up1(0, 1),
                "masked pod-spine uplink must not be chosen"
            );
        }
        f.link_up(up);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..64 {
            seen.insert(f.route(f.topo.sw_leaf(0), &sprayed, &mut rng));
        }
        assert_eq!(seen.len(), 2);
    }

    /// Satellite regression (fails pre-fix): bottleneck selection compared
    /// raw queue depths — the ≤2-hop shortcut. On a 3-tier path with a 4×
    /// faster core, a 10 000 B queue at 100 G drains in 0.8 µs while a
    /// 9 000 B queue at 25 G needs 2.9 µs; the old rule handed HPCC the
    /// fast hop's (deeper) queue and rate, hiding the true bottleneck.
    #[test]
    fn stamping_bottleneck_is_drain_time_not_raw_depth() {
        let mut pkt = data_pkt(1, 100);
        Fabric::stamp_hints(&mut pkt, 9_000, 4_000, 25_000);
        Fabric::stamp_hints(&mut pkt, 10_000, 50_000, 100_000);
        let h = pkt.data_hdr().unwrap().hints;
        assert_eq!(h.qdepth, 9_000, "slow-link hop is the real bottleneck");
        assert_eq!(h.link_mbps, 25_000);
        assert_eq!(h.tx_bytes, 4_000);
        assert_eq!(h.hops, 2);
        // and the triple still rides together when the deep-slow hop wins
        Fabric::stamp_hints(&mut pkt, 40_000, 7_000, 100_000);
        let h = pkt.data_hdr().unwrap().hints;
        assert_eq!((h.qdepth, h.link_mbps, h.tx_bytes, h.hops), (40_000, 100_000, 7_000, 3));
    }

    #[test]
    fn down_links_blackhole_and_flush() {
        let mut f = Fabric::new(leaf_spine_cfg());
        let mut rng = Pcg64::seeded(12);
        let up = f.topo.up_link(0, 0);
        let _ = f.enqueue(up, data_pkt(2, 100), &mut rng);
        assert!(f.queue_bytes(up) > 0);
        assert_eq!(f.link_down(up), 1, "queued packet flushed");
        assert_eq!(f.queue_bytes(up), 0);
        assert_eq!(
            f.enqueue(up, data_pkt(2, 100), &mut rng),
            EnqueueOutcome::Dropped
        );
        assert_eq!(f.drops_link_down, 2);
        f.link_up(up);
        assert!(matches!(
            f.enqueue(up, data_pkt(2, 100), &mut rng),
            EnqueueOutcome::Queued { .. }
        ));
    }

    #[test]
    fn degraded_links_stretch_serialization() {
        let mut f = Fabric::new(leaf_spine_cfg());
        let pkt = data_pkt(2, 1000);
        let up = f.topo.up_link(0, 0);
        let healthy = f.port_tx_ns(up, &pkt);
        f.degrade_link(up, 4);
        assert_eq!(
            f.port_tx_ns(up, &pkt),
            f.cfg.switch_delay_ns + (healthy - f.cfg.switch_delay_ns) * 4
        );
        // degrade(1) restores
        f.degrade_link(up, 1);
        assert_eq!(f.port_tx_ns(up, &pkt), healthy);
    }

    #[test]
    fn core_rate_defaults_to_edge_and_overrides() {
        let f = Fabric::new(leaf_spine_cfg());
        let pkt = data_pkt(2, 1000);
        assert_eq!(f.port_tx_ns(f.topo.up_link(0, 0), &pkt), f.port_tx_ns(2, &pkt));
        assert_eq!(f.link_mbps(f.topo.up_link(0, 0)), 10_000);
        let f2 = Fabric::new(leaf_spine_cfg().with_core_gbps(100.0));
        let core = f2.topo.up_link(0, 0);
        assert!(f2.port_tx_ns(core, &pkt) < f2.port_tx_ns(2, &pkt));
        assert_eq!(f2.link_mbps(core), 100_000);
        assert_eq!(f2.link_mbps(2), 10_000);
    }

    #[test]
    fn spray_jitter_only_in_single_switch_mode() {
        let mut sprayed = data_pkt(1, 10);
        sprayed.spray = true;
        let mut cfg = small_cfg();
        cfg.spray_jitter_ns = 4_000;
        let f = Fabric::new(cfg);
        let mut rng = Pcg64::seeded(13);
        let mut any = false;
        for _ in 0..16 {
            any |= f.spray_delay(&sprayed, &mut rng) > 0;
        }
        assert!(any, "single-switch spray keeps the jitter stand-in");
        let mut cfg = leaf_spine_cfg();
        cfg.spray_jitter_ns = 4_000;
        let f = Fabric::new(cfg);
        for _ in 0..16 {
            assert_eq!(f.spray_delay(&sprayed, &mut rng), 0, "real paths, no fake jitter");
        }
    }

    #[test]
    fn environments_sane() {
        let cl = FabricCfg::cloudlab(8);
        let hs = FabricCfg::hyperstack(8);
        assert!(hs.link_gbps > cl.link_gbps);
        assert!(cl.base_rtt_ns() > 0);
        assert!(hs.bytes_per_ns() > cl.bytes_per_ns());
        // leaf–spine paths are longer: base RTT must grow with the shape
        let ls = FabricCfg::cloudlab(8).with_leaf_spine(2, 2);
        assert!(ls.base_rtt_ns() > cl.base_rtt_ns());
        assert_eq!(ls.path_links(), 4);
        assert_eq!(cl.path_links(), 2);
        // fat-tree paths are longer still (cross-pod worst case)
        let ft = FabricCfg::cloudlab(16).with_fat_tree(2, 2, 2, 2);
        assert!(ft.base_rtt_ns() > ls.base_rtt_ns());
        assert_eq!(ft.path_links(), 6);
    }
}
