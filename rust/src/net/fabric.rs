//! Output-queued ToR switch + host links.
//!
//! Pure state machine: the DES engine (`sim::cluster`) owns event scheduling
//! and asks the fabric what happens when a packet hits a queue. This keeps
//! the fabric unit-testable without an event loop.

use std::collections::VecDeque;

use crate::net::Packet;
use crate::sim::SimTime;
use crate::util::prng::Pcg64;
use crate::verbs::NodeId;

/// Fabric configuration. Defaults model the paper's CloudLab environment
/// (25 GbE ConnectX-5 behind a ToR).
#[derive(Clone, Debug)]
pub struct FabricCfg {
    pub nodes: usize,
    /// Link rate in Gbps (both uplink and downlink).
    pub link_gbps: f64,
    /// One-way propagation per hop (host↔switch), ns.
    pub prop_delay_ns: u64,
    /// Switch forwarding latency, ns.
    pub switch_delay_ns: u64,
    /// Per-output-port buffer capacity, bytes (shared-buffer slice).
    pub queue_cap_bytes: usize,
    /// RED/ECN marking thresholds, bytes.
    pub ecn_kmin: usize,
    pub ecn_kmax: usize,
    pub ecn_pmax: f64,
    /// PFC thresholds (only consulted when the transport requires PFC).
    pub pfc_xoff: usize,
    pub pfc_xon: usize,
    /// Probability a packet is corrupted/dropped in flight (link BER proxy).
    pub corrupt_prob: f64,
    /// Extra uniform delay applied to sprayed packets (multipath skew), ns.
    pub spray_jitter_ns: u64,
    /// Precomputed integer serialization rate in picoseconds per byte —
    /// the per-packet hot path of [`FabricCfg::serialize_ns`] (§Perf:
    /// one u64 multiply + div_ceil instead of an f64 mul/div/ceil per
    /// packet). `0` means "link rate does not divide 8000 ps evenly";
    /// the float formula is used instead. INVARIANT: must equal
    /// `ps_per_byte(link_gbps)` — change the rate only through
    /// [`FabricCfg::with_link_gbps`], which re-derives it; both stock
    /// environments (25 G, 100 G) have exact rates.
    pub ser_ps_per_byte: u64,
}

/// Exact integer picoseconds-per-byte for a link rate in Gbps, or `0`
/// when `8000 / rate` is not an integer (callers then keep f64 math).
/// `serialize_ns` is bit-identical between the two paths whenever this
/// returns non-zero: the exact value is `bytes·pspb/1000`, a rational
/// with denominator 1000, so the one f64 rounding (≤ half-ulp, < 1e-3
/// for any packet below a terabyte) can never move it across an integer
/// boundary — pinned by `serialize_integer_path_matches_float`.
pub fn ps_per_byte(link_gbps: f64) -> u64 {
    if !link_gbps.is_finite() || link_gbps <= 0.0 {
        return 0;
    }
    let pspb = 8000.0 / link_gbps;
    if pspb.fract() == 0.0 && pspb <= 1e9 && 8000.0 / pspb == link_gbps {
        pspb as u64
    } else {
        0
    }
}

impl FabricCfg {
    /// 8-node CloudLab r7525-like environment: 25 GbE, shallow ToR buffers.
    pub fn cloudlab(nodes: usize) -> FabricCfg {
        FabricCfg {
            nodes,
            link_gbps: 25.0,
            prop_delay_ns: 1_000,
            switch_delay_ns: 500,
            queue_cap_bytes: 512 * 1024,
            ecn_kmin: 64 * 1024,
            ecn_kmax: 256 * 1024,
            ecn_pmax: 0.8,
            pfc_xoff: 384 * 1024,
            pfc_xon: 128 * 1024,
            corrupt_prob: 2e-5,
            spray_jitter_ns: 4_000,
            ser_ps_per_byte: ps_per_byte(25.0),
        }
    }

    /// Hyperstack H100 environment: 100 G, deeper buffers, faster fabric.
    pub fn hyperstack(nodes: usize) -> FabricCfg {
        FabricCfg {
            nodes,
            link_gbps: 100.0,
            prop_delay_ns: 600,
            switch_delay_ns: 300,
            queue_cap_bytes: 2 * 1024 * 1024,
            ecn_kmin: 256 * 1024,
            ecn_kmax: 1024 * 1024,
            ecn_pmax: 0.8,
            pfc_xoff: 1536 * 1024,
            pfc_xon: 512 * 1024,
            corrupt_prob: 1e-5,
            spray_jitter_ns: 2_000,
            ser_ps_per_byte: ps_per_byte(100.0),
        }
    }

    /// Change the link rate, keeping the precomputed integer
    /// serialization rate in sync (the two fields must never diverge —
    /// a stale `ser_ps_per_byte` would silently time every packet at
    /// the old rate).
    pub fn with_link_gbps(mut self, gbps: f64) -> Self {
        self.link_gbps = gbps;
        self.ser_ps_per_byte = ps_per_byte(gbps);
        self
    }

    /// Serialization time of `bytes` on a link, ns. Integer fast path
    /// when the rate divides 8000 ps/byte evenly (all stock
    /// environments); bit-identical to the float formula — see
    /// [`ps_per_byte`] and the parity test below.
    pub fn serialize_ns(&self, bytes: usize) -> u64 {
        let pspb = self.ser_ps_per_byte;
        if pspb > 0 {
            (bytes as u64 * pspb).div_ceil(1000)
        } else {
            // Gbps = bits/ns; ns = bits / (bits/ns)
            ((bytes as f64 * 8.0) / self.link_gbps).ceil() as u64
        }
    }

    /// Base RTT (no queueing): 2 hops each way + switch.
    pub fn base_rtt_ns(&self) -> u64 {
        2 * (2 * self.prop_delay_ns + self.switch_delay_ns)
    }

    /// Link bandwidth in bytes/ns.
    pub fn bytes_per_ns(&self) -> f64 {
        self.link_gbps / 8.0
    }
}

/// What happened when a packet was offered to a queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnqueueOutcome {
    /// Queued; `ecn_marked` tells whether RED marked it.
    Queued { ecn_marked: bool },
    /// Tail-dropped: queue full.
    Dropped,
}

/// One output port: FIFO of packets with byte accounting.
#[derive(Debug, Default)]
pub struct Port {
    pub queue: VecDeque<Packet>,
    pub bytes: usize,
    /// Is the port currently serializing a packet?
    pub busy: bool,
    /// PFC: this port's downstream is paused.
    pub paused: bool,
    /// Cumulative bytes this port has transmitted — the busy-time proxy
    /// stamped into [`crate::net::NetHints`] for HPCC-style INT.
    pub tx_bytes: u64,
}

/// The switch: one downlink port per node. (Host uplinks are modeled in the
/// NIC, which serializes onto its own link; contention happens here at the
/// destination downlink — the locus of incast, ECN, and PFC.)
#[derive(Debug)]
pub struct Fabric {
    pub cfg: FabricCfg,
    pub ports: Vec<Port>,
    /// PFC state: when a port crosses XOFF we pause *all* ingress (coarse
    /// class-level PFC — exactly the head-of-line-blocking failure mode the
    /// paper describes in §2.3).
    pub pfc_pause_active: bool,
    /// Statistics.
    pub drops_overflow: u64,
    pub drops_corrupt: u64,
    pub ecn_marks: u64,
    pub pfc_pauses: u64,
    pub forwarded: u64,
}

impl Fabric {
    pub fn new(mut cfg: FabricCfg) -> Fabric {
        // re-derive the cached integer serialization rate: the two cfg
        // fields are pub, and direct `cfg.link_gbps = …` mutation (the
        // established idiom for corrupt_prob etc.) must not leave a
        // stale rate timing every packet
        cfg.ser_ps_per_byte = ps_per_byte(cfg.link_gbps);
        let ports = (0..cfg.nodes).map(|_| Port::default()).collect();
        Fabric {
            cfg,
            ports,
            pfc_pause_active: false,
            drops_overflow: 0,
            drops_corrupt: 0,
            ecn_marks: 0,
            pfc_pauses: 0,
            forwarded: 0,
        }
    }

    /// Offer a packet to the destination's downlink queue.
    pub fn enqueue(&mut self, mut pkt: Packet, rng: &mut Pcg64) -> EnqueueOutcome {
        let port = &mut self.ports[pkt.dst];
        if port.bytes + pkt.size > self.cfg.queue_cap_bytes {
            self.drops_overflow += 1;
            return EnqueueOutcome::Dropped;
        }
        // RED/ECN marking on data packets only (control stays unmarked).
        let mut marked = false;
        if pkt.is_data() {
            let q = port.bytes;
            if q > self.cfg.ecn_kmin {
                let p = if q >= self.cfg.ecn_kmax {
                    1.0
                } else {
                    self.cfg.ecn_pmax * (q - self.cfg.ecn_kmin) as f64
                        / (self.cfg.ecn_kmax - self.cfg.ecn_kmin) as f64
                };
                if rng.chance(p) {
                    pkt.ecn = true;
                    marked = true;
                    self.ecn_marks += 1;
                }
            }
        }
        port.bytes += pkt.size;
        port.queue.push_back(pkt);
        EnqueueOutcome::Queued { ecn_marked: marked }
    }

    /// Pop the head-of-line packet from a port (the engine calls this when
    /// the port finishes serializing the previous packet).
    pub fn dequeue(&mut self, node: NodeId) -> Option<Packet> {
        let port = &mut self.ports[node];
        let pkt = port.queue.pop_front()?;
        port.bytes -= pkt.size;
        port.tx_bytes += pkt.size as u64;
        self.forwarded += 1;
        Some(pkt)
    }

    /// Stamp the uniform telemetry header on a data packet at port
    /// dequeue: the queue depth behind it, its CE mark, and the port's
    /// cumulative tx byte count (busy-time proxy). This is the ONE code
    /// path every CC signal source derives from — DCQCN marks, HPCC INT,
    /// and EQDS edge-queue backoff all read the same `NetHints` (§3.1.3
    /// decoupling: CC feedback is stamped, not synthesized per algorithm).
    pub fn stamp_hints(pkt: &mut Packet, qdepth: usize, tx_bytes: u64) {
        let ecn = pkt.ecn;
        if let crate::net::PktKind::Data(h) = &mut pkt.kind {
            h.hints = crate::net::NetHints {
                qdepth: qdepth.min(u32::MAX as usize) as u32,
                ecn,
                tx_bytes,
            };
        }
    }

    pub fn queue_bytes(&self, node: NodeId) -> usize {
        self.ports[node].bytes
    }

    /// PFC logic: should we assert a pause right now? (Consulted only when
    /// the sending transport requires lossless operation, i.e. RoCE.)
    pub fn pfc_should_pause(&self) -> bool {
        self.ports.iter().any(|p| p.bytes >= self.cfg.pfc_xoff)
    }

    pub fn pfc_should_resume(&self) -> bool {
        self.ports.iter().all(|p| p.bytes <= self.cfg.pfc_xon)
    }

    /// In-flight corruption lottery (applies per packet on the switch→host
    /// leg). Control-plane packets are assumed protected (FEC + retry in the
    /// reliable channel), data/ack are subject to loss.
    pub fn corrupted(&mut self, pkt: &Packet, rng: &mut Pcg64) -> bool {
        if matches!(
            pkt.kind,
            crate::net::PktKind::Ctrl(_)
                | crate::net::PktKind::Pause { .. }
                // EQDS credits ride the protected control class; losing a
                // grant would stall a sender until its WQE deadline
                | crate::net::PktKind::Credit { .. }
                | crate::net::PktKind::PullReq { .. }
        ) {
            return false;
        }
        if rng.chance(self.cfg.corrupt_prob) {
            self.drops_corrupt += 1;
            true
        } else {
            false
        }
    }

    /// Extra delay for sprayed packets (multipath skew).
    pub fn spray_delay(&self, pkt: &Packet, rng: &mut Pcg64) -> u64 {
        if pkt.spray && self.cfg.spray_jitter_ns > 0 {
            rng.below(self.cfg.spray_jitter_ns)
        } else {
            0
        }
    }

    /// Time for the switch to forward + serialize a packet onto a downlink.
    pub fn port_tx_ns(&self, pkt: &Packet) -> SimTime {
        self.cfg.switch_delay_ns + self.cfg.serialize_ns(pkt.size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{DataHdr, PktKind};
    use crate::verbs::MrId;

    fn data_pkt(dst: NodeId, len: usize) -> Packet {
        Packet::data(
            0,
            dst,
            DataHdr {
                dst_qpn: 0,
                src_qpn: 0,
                psn: 0,
                wqe_seq: 0,
                msg_offset: 0,
                len,
                last: false,
                msg_len: len,
                src_mr: MrId(0),
                src_off: 0,
                reth: None,
                stride: 1,
                imm: None,
                deadline: None,
                tx_time: 0,
                hints: crate::net::NetHints::default(),
            },
        )
    }

    fn small_cfg() -> FabricCfg {
        FabricCfg {
            nodes: 2,
            link_gbps: 10.0,
            prop_delay_ns: 100,
            switch_delay_ns: 50,
            queue_cap_bytes: 3000,
            ecn_kmin: 1000,
            ecn_kmax: 2000,
            ecn_pmax: 1.0,
            pfc_xoff: 2500,
            pfc_xon: 500,
            corrupt_prob: 0.0,
            spray_jitter_ns: 0,
            ser_ps_per_byte: ps_per_byte(10.0),
        }
    }

    #[test]
    fn serialize_time() {
        let cfg = small_cfg();
        // 1000 bytes at 10 Gbps = 8000 bits / 10 bits-per-ns = 800 ns
        assert_eq!(cfg.serialize_ns(1000), 800);
    }

    #[test]
    fn ps_per_byte_exact_rates_only() {
        assert_eq!(ps_per_byte(25.0), 320);
        assert_eq!(ps_per_byte(100.0), 80);
        assert_eq!(ps_per_byte(10.0), 800);
        assert_eq!(ps_per_byte(12.5), 640);
        // 8000/7 is not an integer → float fallback
        assert_eq!(ps_per_byte(7.0), 0);
        assert_eq!(ps_per_byte(0.0), 0);
        assert_eq!(ps_per_byte(-1.0), 0);
        assert_eq!(ps_per_byte(f64::NAN), 0);
    }

    /// The satellite contract: the integer picosecond path must be
    /// bit-identical to the float formula across the full packet-size
    /// range for both stock environments (and the 10 G test fabric).
    #[test]
    fn serialize_integer_path_matches_float() {
        for cfg in [
            FabricCfg::cloudlab(8),
            FabricCfg::hyperstack(8),
            small_cfg(),
        ] {
            assert!(cfg.ser_ps_per_byte > 0, "{} Gbps should be exact", cfg.link_gbps);
            let float_ns =
                |bytes: usize| ((bytes as f64 * 8.0) / cfg.link_gbps).ceil() as u64;
            // every size up to jumbo-frame territory…
            for bytes in 0..=16384usize {
                assert_eq!(
                    cfg.serialize_ns(bytes),
                    float_ns(bytes),
                    "{} Gbps @ {bytes} B",
                    cfg.link_gbps
                );
            }
            // …plus train-scale and pathological sizes
            for bytes in [1 << 20, (1 << 20) + 1, 123_456_789, 1 << 33] {
                assert_eq!(cfg.serialize_ns(bytes), float_ns(bytes));
            }
        }
    }

    #[test]
    fn construction_heals_stale_cached_rate() {
        // direct field mutation (the corrupt_prob idiom) leaves the
        // cached integer rate stale; Fabric::new must re-derive it
        let mut cfg = FabricCfg::cloudlab(2);
        cfg.link_gbps = 100.0;
        assert_eq!(cfg.ser_ps_per_byte, 320); // stale
        let f = Fabric::new(cfg);
        assert_eq!(f.cfg.ser_ps_per_byte, 80); // healed
        assert_eq!(f.cfg.serialize_ns(1000), 80);
    }

    #[test]
    fn serialize_float_fallback_when_inexact() {
        let cfg = small_cfg().with_link_gbps(7.0);
        assert_eq!(cfg.ser_ps_per_byte, 0);
        // 1000 B at 7 Gbps = 8000/7 ns = 1142.86 → ceil 1143
        assert_eq!(cfg.serialize_ns(1000), 1143);
        // the setter keeps the integer rate in sync both directions
        assert_eq!(cfg.with_link_gbps(10.0).ser_ps_per_byte, 800);
    }

    #[test]
    fn fifo_order_and_accounting() {
        let mut f = Fabric::new(small_cfg());
        let mut rng = Pcg64::seeded(1);
        assert!(matches!(
            f.enqueue(data_pkt(1, 100), &mut rng),
            EnqueueOutcome::Queued { .. }
        ));
        assert!(matches!(
            f.enqueue(data_pkt(1, 200), &mut rng),
            EnqueueOutcome::Queued { .. }
        ));
        let q0 = f.queue_bytes(1);
        assert!(q0 > 300); // includes headers
        let p1 = f.dequeue(1).unwrap();
        let p2 = f.dequeue(1).unwrap();
        assert!(p1.size < p2.size); // FIFO: 100-byte first
        assert_eq!(f.queue_bytes(1), 0);
        assert!(f.dequeue(1).is_none());
    }

    #[test]
    fn tail_drop_on_overflow() {
        let mut f = Fabric::new(small_cfg());
        let mut rng = Pcg64::seeded(2);
        let mut dropped = false;
        for _ in 0..10 {
            if f.enqueue(data_pkt(1, 1000), &mut rng) == EnqueueOutcome::Dropped {
                dropped = true;
                break;
            }
        }
        assert!(dropped);
        assert!(f.drops_overflow >= 1);
        assert!(f.queue_bytes(1) <= 3000);
    }

    #[test]
    fn ecn_marks_above_kmin() {
        let mut f = Fabric::new(small_cfg());
        let mut rng = Pcg64::seeded(3);
        // fill beyond kmax so marking prob = 1
        let _ = f.enqueue(data_pkt(1, 1000), &mut rng);
        let _ = f.enqueue(data_pkt(1, 1000), &mut rng);
        match f.enqueue(data_pkt(1, 500), &mut rng) {
            EnqueueOutcome::Queued { ecn_marked } => assert!(ecn_marked),
            other => panic!("{other:?}"),
        }
        assert!(f.ecn_marks >= 1);
    }

    #[test]
    fn pfc_thresholds() {
        let mut f = Fabric::new(small_cfg());
        let mut rng = Pcg64::seeded(4);
        assert!(!f.pfc_should_pause());
        let _ = f.enqueue(data_pkt(1, 1400), &mut rng);
        let _ = f.enqueue(data_pkt(1, 1400), &mut rng);
        assert!(f.pfc_should_pause());
        assert!(!f.pfc_should_resume());
        let _ = f.dequeue(1);
        let _ = f.dequeue(1);
        assert!(f.pfc_should_resume());
    }

    #[test]
    fn corruption_respects_kind() {
        let mut cfg = small_cfg();
        cfg.corrupt_prob = 1.0;
        let mut f = Fabric::new(cfg);
        let mut rng = Pcg64::seeded(5);
        assert!(f.corrupted(&data_pkt(1, 10), &mut rng));
        let ctrl = Packet::ctrl(
            0,
            1,
            crate::net::CtrlMsg {
                tag: 0,
                payload: vec![],
            },
        );
        assert!(!f.corrupted(&ctrl, &mut rng));
    }

    #[test]
    fn dequeue_accumulates_tx_bytes_and_stamping_reads_them() {
        let mut f = Fabric::new(small_cfg());
        let mut rng = Pcg64::seeded(6);
        let _ = f.enqueue(data_pkt(1, 100), &mut rng);
        let _ = f.enqueue(data_pkt(1, 200), &mut rng);
        let qlen = f.queue_bytes(1);
        let mut p1 = f.dequeue(1).unwrap();
        let tx1 = f.ports[1].tx_bytes;
        assert_eq!(tx1, p1.size as u64);
        Fabric::stamp_hints(&mut p1, qlen, tx1);
        let h = p1.data_hdr().unwrap().hints;
        assert_eq!(h.qdepth as usize, qlen);
        assert_eq!(h.tx_bytes, tx1);
        assert!(!h.ecn);
        let p2 = f.dequeue(1).unwrap();
        assert_eq!(f.ports[1].tx_bytes, (p1.size + p2.size) as u64);
    }

    #[test]
    fn environments_sane() {
        let cl = FabricCfg::cloudlab(8);
        let hs = FabricCfg::hyperstack(8);
        assert!(hs.link_gbps > cl.link_gbps);
        assert!(cl.base_rtt_ns() > 0);
        assert!(hs.bytes_per_ns() > cl.bytes_per_ns());
    }
}
