//! Packet model and switched fabric.
//!
//! The paper's testbeds are 8-node 25 GbE (CloudLab) and 4/8-node 100 G
//! (Hyperstack) clusters behind a ToR. We model that directly: hosts with
//! uplink/downlink through an output-queued fabric, per-port byte queues,
//! per-hop RED/ECN marking, tail drop, per-port PFC (required by RoCE
//! only), random packet corruption, multipath (ECMP + per-packet
//! spraying), link-level faults, and injected background traffic. The
//! fabric runs either as the seed single ToR, a two-tier leaf–spine
//! Clos, or a three-tier fat-tree ([`topo`], docs/TOPOLOGY.md,
//! docs/SCALE.md); [`flowsim`] adds the hybrid packet/flow fidelity
//! engine for 1k-rank scale sweeps.

pub mod fabric;
pub mod flowsim;
pub mod topo;
pub mod traffic;

pub use fabric::{ps_per_byte, EnqueueOutcome, Fabric, FabricCfg, MarkingProfile, Port};
pub use flowsim::{FidelityMode, FidelityPolicy, Flow, FlowId, FlowSim, FluidLink};
pub use topo::{LinkDst, LinkId, NetFault, PartitionMap, SwitchCode, Topology, TopologyKind};
pub use traffic::BgTraffic;

use crate::sim::SimTime;
use crate::verbs::{MrId, NodeId, Qpn};

/// Fixed per-packet wire overhead: Eth(14) + IP(20) + UDP(8) + BTH(12) +
/// ICRC(4) = 58 B (RoCEv2 framing).
pub const WIRE_HDR_BYTES: usize = 58;
/// RETH adds VA(8) + rkey(4) + length(4) = 16 B.
pub const RETH_BYTES: usize = 16;
/// OptiNIC extends the header by 2 B for the stride parameter (§3.3).
pub const STRIDE_HDR_BYTES: usize = 2;

/// RDMA Extended Transport Header: remote placement info. OptiNIC puts this
/// on *every* fragment (self-describing packets, §3.1.1); classic transports
/// only on the first packet of a message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RethHdr {
    pub mr: MrId,
    pub offset: usize,
    pub rkey: u32,
}

/// Uniform in-network telemetry header, stamped/accumulated by the fabric
/// on every data packet at each port dequeue and echoed verbatim on CC
/// feedback. This is the single source all congestion-control signals
/// derive from: DCQCN reads `ecn`, HPCC reads `qdepth`/`tx_bytes`/
/// `link_mbps` (INT), delay-based schemes ignore it entirely (they use
/// echoed timestamps). One stamping code path means no per-algorithm
/// branches anywhere in the fabric or transports.
///
/// Multi-hop semantics: the slowest-draining queue along the path
/// (`qdepth / link_mbps`; raw depth when rates match) is the bottleneck
/// — its depth, busy-time counter, and link rate ride together; CE marks
/// OR in across hops; `hops` counts stamping switches. With one hop this
/// reduces exactly to the seed single-switch stamping.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetHints {
    /// Max egress queue depth (bytes) behind this packet across stamped
    /// hops — the bottleneck depth.
    pub qdepth: u32,
    /// CE mark (RED/ECN) — OR of the wire bit across stamping hops.
    pub ecn: bool,
    /// Cumulative bytes the bottleneck port has transmitted — the port
    /// busy-time proxy HPCC's utilization estimate uses (busy time =
    /// tx_bytes / link rate). Always the bottleneck hop's OWN counter,
    /// so it pairs correctly with `link_mbps`; a bottleneck migration
    /// between samples yields one zero-Δ reading, which HPCC guards.
    pub tx_bytes: u64,
    /// Bottleneck link rate, Mbps (0 = not stamped; consumers fall back
    /// to the edge line rate).
    pub link_mbps: u32,
    /// Stamping hops this header accumulated (switch egress ports).
    pub hops: u8,
}

impl NetHints {
    /// Coalesce feedback for several delivered packets into one echo:
    /// marks OR together, the slowest-draining bottleneck wins
    /// (`qdepth / link_mbps` by integer cross-multiply, reducing to the
    /// raw depth comparison when the rates match — the pre-fat-tree
    /// behavior) — carrying its link rate AND its tx counter together,
    /// so the triple stays self-consistent for HPCC's arithmetic.
    pub fn merge(&mut self, other: &NetHints) {
        let slower = if self.link_mbps == 0 || other.link_mbps == 0 {
            other.qdepth > self.qdepth // unrated hint: depth is all we have
        } else {
            other.qdepth as u64 * self.link_mbps as u64
                > self.qdepth as u64 * other.link_mbps as u64
        };
        if slower || self.hops == 0 {
            self.qdepth = other.qdepth;
            self.link_mbps = other.link_mbps;
            self.tx_bytes = other.tx_bytes;
        } else if self.link_mbps == other.link_mbps {
            // same bottleneck port across the coalesced packets: keep
            // the freshest (largest) counter reading
            self.tx_bytes = self.tx_bytes.max(other.tx_bytes);
        }
        self.ecn |= other.ecn;
        self.hops = self.hops.max(other.hops);
    }
}

/// Data-fragment header. Carries both the classic PSN (used by the reliable
/// baselines) and OptiNIC's per-message `wqe_seq` + explicit `msg_offset`.
#[derive(Clone, Copy, Debug)]
pub struct DataHdr {
    pub dst_qpn: Qpn,
    pub src_qpn: Qpn,
    /// Packet sequence number within the connection (reliable transports).
    pub psn: u32,
    /// Per-message sequence number (OptiNIC §3.1.1).
    pub wqe_seq: u32,
    /// Byte offset of this fragment within the message (self-describing).
    pub msg_offset: usize,
    /// Payload bytes carried.
    pub len: usize,
    /// Explicitly marked last fragment.
    pub last: bool,
    /// Total message length.
    pub msg_len: usize,
    /// Simulated DMA source (sender's registered memory).
    pub src_mr: MrId,
    pub src_off: usize,
    /// Remote placement (always present for OptiNIC; first-packet-only for
    /// classic one-sided ops).
    pub reth: Option<RethHdr>,
    /// Stride parameter for interleaved placement (1 = contiguous).
    pub stride: u16,
    /// Immediate value (delivered on the last fragment).
    pub imm: Option<u32>,
    /// Piggybacked deadline for READ responses (§3.1.2).
    pub deadline: Option<SimTime>,
    /// Transmit timestamp for delay-based CC (TIMELY/Swift).
    pub tx_time: SimTime,
    /// Uniform in-band telemetry stamped by the switch at dequeue.
    pub hints: NetHints,
}

/// Acknowledgment header. Reliable transports use `cumulative_psn` (+
/// optional SACK ranges for selective repeat); OptiNIC uses ACKs purely as
/// CC feedback (per-fragment, best effort).
#[derive(Clone, Debug)]
pub struct AckHdr {
    pub dst_qpn: Qpn,
    pub cumulative_psn: u32,
    /// Selective-ACK block (IRN/SRNIC/Falcon): (start_psn, end_psn) incl.
    /// One block per ACK (per-packet ACKs make multi-block SACKs moot) —
    /// inline to keep the ACK hot path allocation-free (§Perf).
    pub sack: Option<(u32, u32)>,
    /// Echo of the data packet's tx_time for RTT computation.
    pub echo_tx_time: SimTime,
    /// Echoed telemetry from the ACKed data packet(s) — merged when the
    /// receiver coalesces several fragments into one feedback packet.
    pub hints: NetHints,
    /// Bytes newly delivered (OptiNIC CC feedback granularity).
    pub acked_bytes: usize,
}

/// Negative ack (out-of-order notification for IRN-style loss detection).
#[derive(Clone, Copy, Debug)]
pub struct NackHdr {
    pub dst_qpn: Qpn,
    /// First missing PSN.
    pub missing_psn: u32,
}

/// Reliable control-plane message (collective handshakes, timeout-statistic
/// exchange). The paper routes these over the pre-existing reliable channel
/// (§3.1.2 end); we model that channel as loss-free with base RTT.
#[derive(Clone, Debug)]
pub struct CtrlMsg {
    pub tag: u64,
    pub payload: Vec<u8>,
}

#[derive(Clone, Debug)]
pub enum PktKind {
    Data(DataHdr),
    Ack(AckHdr),
    Nack(NackHdr),
    /// DCQCN congestion-notification packet.
    Cnp { dst_qpn: Qpn },
    /// EQDS-style credit grant.
    Credit { dst_qpn: Qpn, bytes: usize },
    /// EQDS pull request: sender announces pending demand to the receiver.
    PullReq { dst_qpn: Qpn, bytes: usize },
    /// Per-port PFC pause/resume frame (switch → host): pauses only the
    /// sender's traffic headed to `for_dst`'s edge port, not the whole
    /// data class (the global-pause head-of-line bug this replaced).
    Pause { xoff: bool, for_dst: NodeId },
    /// Background (cross-tenant) traffic: occupies queues and bandwidth,
    /// sunk at the host NIC.
    Bg,
    /// Reliable control-plane message. Boxed: control messages are rare
    /// (handshakes, stat exchanges) but carry an open-ended payload —
    /// keeping them behind a pointer means control-plane growth can
    /// never widen the hot-path `Packet`/`Event` union that every data
    /// fragment is copied through.
    Ctrl(Box<CtrlMsg>),
}

// ---- hot-path footprint guards (§Perf) -------------------------------------
// `Packet` rides inside engine events and egress trains; its size is set
// by the fattest `PktKind` variant (`Data(DataHdr)`). These compile-time
// assertions make footprint regressions fail the build loudly instead of
// silently taxing every queue push. Exact layout is compiler-chosen; the
// caps below hold on 64-bit targets with headroom over the current
// ~136-byte `DataHdr` (the leaf–spine rework grew `NetHints` by 8 bytes
// for the bottleneck link rate + hop count — a deliberate, sized trade).
const _: () = assert!(std::mem::size_of::<PktKind>() <= 152);
const _: () = assert!(std::mem::size_of::<Packet>() <= 184);
// the boxed control variant must stay pointer-sized — if `CtrlMsg` ever
// leaks back inline this fires
const _: () = assert!(std::mem::size_of::<Box<CtrlMsg>>() == 8);
// `Data` must remain the size driver: a new variant outgrowing it means
// the hot path pays for a rare packet class
const _: () = assert!(std::mem::size_of::<DataHdr>() + 16 >= std::mem::size_of::<PktKind>());

#[derive(Clone, Debug)]
pub struct Packet {
    pub src: NodeId,
    pub dst: NodeId,
    /// Total wire size (headers + payload).
    pub size: usize,
    /// ECN CE mark (set by the switch under congestion).
    pub ecn: bool,
    /// Whether this packet may be sprayed across paths (adds jitter,
    /// reorders). Falcon/UEC-style multipath.
    pub spray: bool,
    pub kind: PktKind,
}

impl Packet {
    pub fn data(src: NodeId, dst: NodeId, hdr: DataHdr) -> Packet {
        let mut size = WIRE_HDR_BYTES + hdr.len;
        if hdr.reth.is_some() {
            size += RETH_BYTES;
        }
        if hdr.stride > 1 {
            size += STRIDE_HDR_BYTES;
        }
        Packet {
            src,
            dst,
            size,
            ecn: false,
            spray: false,
            kind: PktKind::Data(hdr),
        }
    }

    pub fn ack(src: NodeId, dst: NodeId, hdr: AckHdr) -> Packet {
        let size = WIRE_HDR_BYTES + 4 + if hdr.sack.is_some() { 8 } else { 0 };
        Packet {
            src,
            dst,
            size,
            ecn: false,
            spray: false,
            kind: PktKind::Ack(hdr),
        }
    }

    pub fn nack(src: NodeId, dst: NodeId, hdr: NackHdr) -> Packet {
        Packet {
            src,
            dst,
            size: WIRE_HDR_BYTES + 4,
            ecn: false,
            spray: false,
            kind: PktKind::Nack(hdr),
        }
    }

    pub fn cnp(src: NodeId, dst: NodeId, dst_qpn: Qpn) -> Packet {
        Packet {
            src,
            dst,
            size: WIRE_HDR_BYTES,
            ecn: false,
            spray: false,
            kind: PktKind::Cnp { dst_qpn },
        }
    }

    pub fn credit(src: NodeId, dst: NodeId, dst_qpn: Qpn, bytes: usize) -> Packet {
        Packet {
            src,
            dst,
            size: WIRE_HDR_BYTES + 4,
            ecn: false,
            spray: false,
            kind: PktKind::Credit { dst_qpn, bytes },
        }
    }

    pub fn pull_req(src: NodeId, dst: NodeId, dst_qpn: Qpn, bytes: usize) -> Packet {
        Packet {
            src,
            dst,
            size: WIRE_HDR_BYTES + 4,
            ecn: false,
            spray: false,
            kind: PktKind::PullReq { dst_qpn, bytes },
        }
    }

    /// Reliable control-plane message (boxed off the hot-path union).
    pub fn ctrl(src: NodeId, dst: NodeId, msg: CtrlMsg) -> Packet {
        Packet {
            src,
            dst,
            size: WIRE_HDR_BYTES + msg.payload.len(),
            ecn: false,
            spray: false,
            kind: PktKind::Ctrl(Box::new(msg)),
        }
    }

    pub fn is_data(&self) -> bool {
        matches!(self.kind, PktKind::Data(_))
    }

    pub fn data_hdr(&self) -> Option<&DataHdr> {
        match &self.kind {
            PktKind::Data(h) => Some(h),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hdr(len: usize, reth: bool, stride: u16) -> DataHdr {
        DataHdr {
            dst_qpn: 1,
            src_qpn: 2,
            psn: 0,
            wqe_seq: 0,
            msg_offset: 0,
            len,
            last: false,
            msg_len: len,
            src_mr: MrId(0),
            src_off: 0,
            reth: reth.then_some(RethHdr {
                mr: MrId(1),
                offset: 0,
                rkey: 1,
            }),
            stride,
            imm: None,
            deadline: None,
            tx_time: 0,
            hints: NetHints::default(),
        }
    }

    #[test]
    fn wire_size_accounting() {
        let p = Packet::data(0, 1, hdr(1000, false, 1));
        assert_eq!(p.size, WIRE_HDR_BYTES + 1000);
        let p = Packet::data(0, 1, hdr(1000, true, 1));
        assert_eq!(p.size, WIRE_HDR_BYTES + RETH_BYTES + 1000);
        let p = Packet::data(0, 1, hdr(1000, true, 8));
        assert_eq!(p.size, WIRE_HDR_BYTES + RETH_BYTES + STRIDE_HDR_BYTES + 1000);
    }

    #[test]
    fn ack_size_grows_with_sack() {
        let a = Packet::ack(
            0,
            1,
            AckHdr {
                dst_qpn: 1,
                cumulative_psn: 10,
                sack: Some((12, 14)),
                echo_tx_time: 0,
                hints: NetHints::default(),
                acked_bytes: 0,
            },
        );
        assert_eq!(a.size, WIRE_HDR_BYTES + 4 + 8);
    }

    #[test]
    fn ctrl_packets_are_boxed_and_sized() {
        let p = Packet::ctrl(
            0,
            1,
            CtrlMsg {
                tag: 7,
                payload: vec![0u8; 100],
            },
        );
        assert_eq!(p.size, WIRE_HDR_BYTES + 100);
        match p.kind {
            PktKind::Ctrl(m) => {
                assert_eq!(m.tag, 7);
                assert_eq!(m.payload.len(), 100);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hints_merge_coalesces() {
        let mut a = NetHints {
            qdepth: 100,
            ecn: false,
            tx_bytes: 5,
            link_mbps: 25_000,
            hops: 1,
        };
        a.merge(&NetHints {
            qdepth: 40,
            ecn: true,
            tx_bytes: 9,
            link_mbps: 100_000,
            hops: 3,
        });
        assert_eq!(
            a,
            NetHints {
                qdepth: 100,
                ecn: true,
                // a shallower echo from a DIFFERENT port displaces
                // neither the bottleneck rate nor its counter
                tx_bytes: 5,
                link_mbps: 25_000,
                hops: 3,
            }
        );
        // same bottleneck port: the freshest counter reading wins
        a.merge(&NetHints {
            qdepth: 40,
            ecn: false,
            tx_bytes: 9,
            link_mbps: 25_000,
            hops: 1,
        });
        assert_eq!(a.tx_bytes, 9);
        assert_eq!(a.qdepth, 100);
        // a deeper echo brings its own link rate AND counter along
        a.merge(&NetHints {
            qdepth: 500,
            ecn: false,
            tx_bytes: 2,
            link_mbps: 100_000,
            hops: 1,
        });
        assert_eq!(a.qdepth, 500);
        assert_eq!(a.link_mbps, 100_000);
        assert_eq!(a.tx_bytes, 2);
        // merging into a fresh (never-stamped) header adopts the echo
        let mut fresh = NetHints::default();
        fresh.merge(&NetHints {
            qdepth: 0,
            ecn: false,
            tx_bytes: 1,
            link_mbps: 25_000,
            hops: 1,
        });
        assert_eq!(fresh.link_mbps, 25_000);
    }

    /// Satellite regression (fails pre-fix): merge compared raw depths, so
    /// an echo from a deeper queue on a 4× faster core link displaced the
    /// true (slower-draining) bottleneck — the same ≤2-hop shortcut fixed
    /// in `Fabric::stamp_hints`.
    #[test]
    fn hints_merge_prefers_drain_time_over_raw_depth() {
        let mut a = NetHints {
            qdepth: 9_000,
            ecn: false,
            tx_bytes: 4,
            link_mbps: 25_000,
            hops: 2,
        };
        // deeper but fast-draining: 10 000/100 G drains before 9 000/25 G
        a.merge(&NetHints {
            qdepth: 10_000,
            ecn: false,
            tx_bytes: 8,
            link_mbps: 100_000,
            hops: 3,
        });
        assert_eq!((a.qdepth, a.link_mbps, a.tx_bytes), (9_000, 25_000, 4));
        assert_eq!(a.hops, 3);
        // slower-draining despite equal depth on a slower link: adopts
        a.merge(&NetHints {
            qdepth: 9_000,
            ecn: true,
            tx_bytes: 6,
            link_mbps: 10_000,
            hops: 2,
        });
        assert_eq!((a.qdepth, a.link_mbps, a.tx_bytes), (9_000, 10_000, 6));
        assert!(a.ecn);
    }
}
