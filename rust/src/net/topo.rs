//! Multi-tier topology indexing: which links exist, who they feed, and
//! how a packet picks its next hop.
//!
//! The seed model was one ToR switch with a queue per destination — fine
//! for an 8-node testbed, but the paper's headline claims (3.5× lower p99
//! CCT, per-packet spraying, multi-tenant interference) are *network-path*
//! effects that only emerge with genuine multi-hop contention. This module
//! is the pure index math of a two-tier leaf–spine (Clos) fabric:
//!
//! * hosts attach to leaves (`nodes / leaves` per leaf);
//! * every leaf has one egress port per spine (up) and one per attached
//!   host (down); every spine has one egress port per leaf (down);
//! * non-sprayed flows pick their spine by a deterministic ECMP hash of
//!   `(src, dst, flow label)`; sprayed packets (OptiNIC/UCCL/Falcon) pick
//!   a spine per packet — real path diversity, replacing the old
//!   `spray_jitter_ns` random-delay stand-in.
//!
//! Link state (queues, faults, PFC) lives in [`crate::net::Fabric`], which
//! owns one [`crate::net::fabric::Port`] per [`LinkId`] defined here;
//! routing that must consult link state (fault masks) lives there too.
//! The single-switch mode is the degenerate case `LinkId == NodeId`, so
//! every existing single-tier experiment runs through the same code with
//! identical link indices. See docs/TOPOLOGY.md.

use crate::net::{Packet, PktKind};
use crate::verbs::NodeId;

/// Index into the fabric's egress-port array. Edge (leaf→host) links are
/// `0..nodes` in BOTH topology modes (`LinkId == NodeId` there); core
/// links follow.
pub type LinkId = usize;

/// Encoded switch location (`u32` so it rides cheaply inside engine
/// events): leaves are `0..leaves`, spines are `leaves..leaves+spines`.
/// The single-switch mode has exactly one switch, code `0`.
pub type SwitchCode = u32;

/// Fabric shape selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    /// One ToR switch, one queue per destination (the seed model).
    SingleSwitch,
    /// Two-tier Clos: `leaves` leaf switches, `spines` spine switches,
    /// `nodes / leaves` hosts per leaf, full leaf↔spine mesh.
    LeafSpine { leaves: usize, spines: usize },
}

impl TopologyKind {
    pub fn is_multitier(&self) -> bool {
        matches!(self, TopologyKind::LeafSpine { .. })
    }

    /// Canonical spelling for tables / sweep rows / CLI.
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::SingleSwitch => "single",
            TopologyKind::LeafSpine { .. } => "leaf-spine",
        }
    }
}

/// What sits at the downstream end of an egress link.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkDst {
    Host(NodeId),
    Leaf(usize),
    Spine(usize),
}

/// Link-level fault actions, delivered through the engine's
/// `Event::NetFault` (scenario builders live in `hw::fault`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetFault {
    /// Blackhole: the link drops its queue and every packet offered to it
    /// until a matching [`NetFault::LinkUp`].
    LinkDown(LinkId),
    /// Restore a downed link (clears the routing mask too).
    LinkUp(LinkId),
    /// Routing convergence: mask a (still-down) link out of ECMP/spray
    /// path choice. Scheduled automatically `reroute_ns` after a
    /// `LinkDown` — the window in between models pre-convergence loss.
    RerouteOut(LinkId),
    /// Multiply the link's serialization time by `factor` (1 = healthy).
    Degrade(LinkId, u32),
}

/// The pure index map of a fabric topology.
#[derive(Clone, Copy, Debug)]
pub struct Topology {
    pub kind: TopologyKind,
    pub nodes: usize,
    /// `nodes` in single-switch mode; `nodes / leaves` otherwise.
    pub hosts_per_leaf: usize,
}

impl Topology {
    pub fn new(kind: TopologyKind, nodes: usize) -> Topology {
        let hosts_per_leaf = match kind {
            TopologyKind::SingleSwitch => nodes,
            TopologyKind::LeafSpine { leaves, spines } => {
                assert!(leaves > 0 && spines > 0, "empty tier");
                assert!(
                    nodes % leaves == 0,
                    "{nodes} hosts do not divide across {leaves} leaves"
                );
                nodes / leaves
            }
        };
        Topology {
            kind,
            nodes,
            hosts_per_leaf,
        }
    }

    /// Total egress links the fabric must own queues for.
    pub fn n_links(&self) -> usize {
        match self.kind {
            TopologyKind::SingleSwitch => self.nodes,
            // leaf→host (nodes) + leaf→spine + spine→leaf
            TopologyKind::LeafSpine { leaves, spines } => self.nodes + 2 * leaves * spines,
        }
    }

    /// Edge links (switch→host) are the PFC/incast locus and keep their
    /// seed indices: link `n` feeds host `n`.
    pub fn is_edge(&self, link: LinkId) -> bool {
        link < self.nodes
    }

    pub fn host_leaf(&self, node: NodeId) -> usize {
        node / self.hosts_per_leaf
    }

    pub fn host_link(&self, node: NodeId) -> LinkId {
        node
    }

    /// Leaf `l`'s egress toward spine `s`. Bounds-checked: an
    /// out-of-range index would silently alias another leaf's link.
    pub fn up_link(&self, leaf: usize, spine: usize) -> LinkId {
        let TopologyKind::LeafSpine { leaves, spines } = self.kind else {
            unreachable!("up_link in single-switch mode");
        };
        assert!(leaf < leaves && spine < spines, "up_link({leaf},{spine}) out of range");
        self.nodes + leaf * spines + spine
    }

    /// Spine `s`'s egress toward leaf `l`. Bounds-checked like
    /// [`Topology::up_link`].
    pub fn down_link(&self, spine: usize, leaf: usize) -> LinkId {
        let TopologyKind::LeafSpine { leaves, spines } = self.kind else {
            unreachable!("down_link in single-switch mode");
        };
        assert!(leaf < leaves && spine < spines, "down_link({spine},{leaf}) out of range");
        self.nodes + leaves * spines + spine * leaves + leaf
    }

    pub fn link_dst(&self, link: LinkId) -> LinkDst {
        if link < self.nodes {
            return LinkDst::Host(link);
        }
        let TopologyKind::LeafSpine { leaves, spines } = self.kind else {
            unreachable!("core link in single-switch mode");
        };
        let rel = link - self.nodes;
        if rel < leaves * spines {
            LinkDst::Spine(rel % spines)
        } else {
            let rel = rel - leaves * spines;
            LinkDst::Leaf(rel % leaves)
        }
    }

    /// Every link touching spine `s` (both directions) — the unit a spine
    /// failure takes down. Fails fast on a nonexistent spine rather than
    /// letting the bad index alias other links at fault-fire time.
    pub fn spine_links(&self, spine: usize) -> Vec<LinkId> {
        let TopologyKind::LeafSpine { leaves, spines } = self.kind else {
            return Vec::new();
        };
        assert!(spine < spines, "spine {spine} out of range (fabric has {spines})");
        (0..leaves)
            .flat_map(|l| [self.up_link(l, spine), self.down_link(spine, l)])
            .collect()
    }

    /// Switch a host's uplink lands on.
    pub fn ingress_switch(&self, src: NodeId) -> SwitchCode {
        match self.kind {
            TopologyKind::SingleSwitch => 0,
            TopologyKind::LeafSpine { .. } => self.host_leaf(src) as SwitchCode,
        }
    }

    pub fn sw_leaf(&self, leaf: usize) -> SwitchCode {
        leaf as SwitchCode
    }

    pub fn sw_spine(&self, spine: usize) -> SwitchCode {
        let TopologyKind::LeafSpine { leaves, .. } = self.kind else {
            unreachable!("spine in single-switch mode");
        };
        (leaves + spine) as SwitchCode
    }

    /// Links a cross-fabric (worst-case) path traverses one way — feeds
    /// `CcCtx::hops` and the base-RTT model.
    pub fn path_links(&self) -> u32 {
        match self.kind {
            TopologyKind::SingleSwitch => 2, // host→ToR→host
            TopologyKind::LeafSpine { .. } => 4, // host→leaf→spine→leaf→host
        }
    }

    /// Switch traversals on that worst-case path.
    pub fn path_switches(&self) -> u32 {
        match self.kind {
            TopologyKind::SingleSwitch => 1,
            TopologyKind::LeafSpine { .. } => 3,
        }
    }

    /// Flow label for ECMP hashing: keeps one flow's packets on one path
    /// (no reordering for transports that can't tolerate it) while
    /// spreading distinct QPs across spines.
    pub fn flow_label(pkt: &Packet) -> u64 {
        match &pkt.kind {
            PktKind::Data(h) => (h.dst_qpn as u64) << 32 | h.src_qpn as u64,
            PktKind::Ack(h) => h.dst_qpn as u64,
            PktKind::Nack(h) => h.dst_qpn as u64,
            PktKind::Cnp { dst_qpn }
            | PktKind::Credit { dst_qpn, .. }
            | PktKind::PullReq { dst_qpn, .. } => *dst_qpn as u64,
            // background tenants / control / pause frames: per-pair hashing
            PktKind::Bg | PktKind::Ctrl(_) | PktKind::Pause { .. } => 0,
        }
    }

    /// Deterministic ECMP hash (splitmix64 over the 5-tuple stand-in).
    /// Stable across runs — determinism rides on it.
    pub fn ecmp_hash(src: NodeId, dst: NodeId, label: u64) -> u64 {
        let mut z = (src as u64)
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add((dst as u64) << 32)
            .wrapping_add(label)
            .wrapping_add(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ls(nodes: usize, leaves: usize, spines: usize) -> Topology {
        Topology::new(TopologyKind::LeafSpine { leaves, spines }, nodes)
    }

    #[test]
    fn single_switch_degenerates_to_seed_indices() {
        let t = Topology::new(TopologyKind::SingleSwitch, 8);
        assert_eq!(t.n_links(), 8);
        assert_eq!(t.host_link(5), 5);
        assert!(t.is_edge(7));
        assert_eq!(t.link_dst(3), LinkDst::Host(3));
        assert_eq!(t.ingress_switch(6), 0);
        assert_eq!(t.path_links(), 2);
        assert_eq!(t.path_switches(), 1);
        assert!(!t.kind.is_multitier());
    }

    #[test]
    fn link_indices_are_a_partition() {
        let t = ls(8, 2, 3);
        assert_eq!(t.hosts_per_leaf, 4);
        assert_eq!(t.n_links(), 8 + 2 * 2 * 3);
        // every link id maps to exactly one (kind, endpoints) and the
        // constructors invert link_dst
        let mut seen = vec![false; t.n_links()];
        for n in 0..8 {
            let l = t.host_link(n);
            assert_eq!(t.link_dst(l), LinkDst::Host(n));
            assert!(!seen[l]);
            seen[l] = true;
        }
        for leaf in 0..2 {
            for spine in 0..3 {
                let up = t.up_link(leaf, spine);
                assert_eq!(t.link_dst(up), LinkDst::Spine(spine));
                assert!(!seen[up], "up_link collision at {up}");
                seen[up] = true;
                let down = t.down_link(spine, leaf);
                assert_eq!(t.link_dst(down), LinkDst::Leaf(leaf));
                assert!(!seen[down], "down_link collision at {down}");
                seen[down] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "unreferenced link ids");
    }

    #[test]
    fn hosts_map_to_leaves_in_blocks() {
        let t = ls(8, 2, 2);
        assert_eq!(t.host_leaf(0), 0);
        assert_eq!(t.host_leaf(3), 0);
        assert_eq!(t.host_leaf(4), 1);
        assert_eq!(t.host_leaf(7), 1);
        assert_eq!(t.ingress_switch(5), t.sw_leaf(1));
        assert_eq!(t.path_links(), 4);
        assert_eq!(t.path_switches(), 3);
    }

    #[test]
    fn spine_links_cover_both_directions() {
        let t = ls(4, 2, 2);
        let links = t.spine_links(1);
        assert_eq!(links.len(), 4); // 2 leaves × {up, down}
        assert!(links.contains(&t.up_link(0, 1)));
        assert!(links.contains(&t.up_link(1, 1)));
        assert!(links.contains(&t.down_link(1, 0)));
        assert!(links.contains(&t.down_link(1, 1)));
        // and none of spine 0's
        assert!(!links.contains(&t.up_link(0, 0)));
    }

    #[test]
    fn ecmp_hash_is_stable_and_spreads() {
        // stability: the same tuple always hashes identically
        assert_eq!(
            Topology::ecmp_hash(1, 2, 77),
            Topology::ecmp_hash(1, 2, 77)
        );
        // spread: distinct labels land on both of 2 spines eventually
        let hits: Vec<usize> = (0..32)
            .map(|label| (Topology::ecmp_hash(0, 5, label) % 2) as usize)
            .collect();
        assert!(hits.contains(&0) && hits.contains(&1), "degenerate hash");
    }

    #[test]
    #[should_panic]
    fn nodes_must_divide_leaves() {
        ls(7, 2, 2);
    }
}
