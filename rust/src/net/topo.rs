//! Multi-tier topology indexing: which links exist, who they feed, and
//! how a packet picks its next hop.
//!
//! The seed model was one ToR switch with a queue per destination — fine
//! for an 8-node testbed, but the paper's headline claims (3.5× lower p99
//! CCT, per-packet spraying, multi-tenant interference) are *network-path*
//! effects that only emerge with genuine multi-hop contention. This module
//! is the pure index math of the Clos family:
//!
//! * two-tier leaf–spine: hosts attach to leaves (`nodes / leaves` per
//!   leaf); every leaf has one egress port per spine (up) and one per
//!   attached host (down); every spine has one egress port per leaf;
//! * three-tier fat-tree / multi-pod Clos ([`TopologyKind::FatTree`]):
//!   pods of (leaves × pod-spines) with a shared core tier above, the
//!   shape 1k–10k-rank clusters actually run (docs/SCALE.md);
//! * non-sprayed flows pick their next hop by a deterministic ECMP hash
//!   of `(src, dst, flow label)` — salted per tier in fat-tree mode so
//!   the up-level choices decorrelate; sprayed packets (OptiNIC/UCCL/
//!   Falcon) pick per packet — real path diversity, replacing the old
//!   `spray_jitter_ns` random-delay stand-in.
//!
//! Link state (queues, faults, PFC) lives in [`crate::net::Fabric`], which
//! owns one [`crate::net::fabric::Port`] per [`LinkId`] defined here;
//! routing that must consult link state (fault masks) lives there too.
//! The single-switch mode is the degenerate case `LinkId == NodeId`;
//! edge links keep `LinkId == NodeId` in EVERY mode, so single-switch
//! and leaf–spine experiments reproduce through the same code with
//! identical link indices. See docs/TOPOLOGY.md and docs/SCALE.md.

use crate::net::{Packet, PktKind};
use crate::verbs::NodeId;

/// Index into the fabric's egress-port array. Edge (leaf→host) links are
/// `0..nodes` in BOTH topology modes (`LinkId == NodeId` there); core
/// links follow.
pub type LinkId = usize;

/// Encoded switch location (`u32` so it rides cheaply inside engine
/// events): leaves are `0..leaves`, spines are `leaves..leaves+spines`,
/// and fat-tree cores follow the spines. The single-switch mode has
/// exactly one switch, code `0`.
pub type SwitchCode = u32;

/// Fabric shape selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    /// One ToR switch, one queue per destination (the seed model).
    SingleSwitch,
    /// Two-tier Clos: `leaves` leaf switches, `spines` spine switches,
    /// `nodes / leaves` hosts per leaf, full leaf↔spine mesh.
    LeafSpine { leaves: usize, spines: usize },
    /// Three-tier fat-tree / multi-pod Clos: `pods` pods, each with
    /// `leaves_per_pod` leaves fully meshed to `spines_per_pod` pod
    /// spines; every pod spine is fully meshed to `core` core switches.
    /// Hosts divide evenly across the `pods × leaves_per_pod` leaves.
    /// Oversubscription is the leaf's host:uplink ratio
    /// ([`Topology::oversubscription`]).
    FatTree {
        pods: usize,
        leaves_per_pod: usize,
        spines_per_pod: usize,
        core: usize,
    },
}

impl TopologyKind {
    pub fn is_multitier(&self) -> bool {
        !matches!(self, TopologyKind::SingleSwitch)
    }

    /// Canonical spelling for tables / sweep rows / CLI.
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::SingleSwitch => "single",
            TopologyKind::LeafSpine { .. } => "leaf-spine",
            TopologyKind::FatTree { .. } => "fat-tree",
        }
    }
}

/// What sits at the downstream end of an egress link. `Spine` carries the
/// GLOBAL pod-spine index (`pod * spines_per_pod + local`) in fat-tree
/// mode, matching [`Topology::sw_spine`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkDst {
    Host(NodeId),
    Leaf(usize),
    Spine(usize),
    /// Fat-tree core switch (tier above the pod spines).
    Core(usize),
}

/// Link-level fault actions, delivered through the engine's
/// `Event::NetFault` (scenario builders live in `hw::fault`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetFault {
    /// Blackhole: the link drops its queue and every packet offered to it
    /// until a matching [`NetFault::LinkUp`].
    LinkDown(LinkId),
    /// Restore a downed link (clears the routing mask too).
    LinkUp(LinkId),
    /// Routing convergence: mask a (still-down) link out of ECMP/spray
    /// path choice. Scheduled automatically `reroute_ns` after a
    /// `LinkDown` — the window in between models pre-convergence loss.
    RerouteOut(LinkId),
    /// Multiply the link's serialization time by `factor` (1 = healthy).
    Degrade(LinkId, u32),
}

/// The pure index map of a fabric topology.
#[derive(Clone, Copy, Debug)]
pub struct Topology {
    pub kind: TopologyKind,
    pub nodes: usize,
    /// `nodes` in single-switch mode; `nodes / leaves` otherwise.
    pub hosts_per_leaf: usize,
}

impl Topology {
    pub fn new(kind: TopologyKind, nodes: usize) -> Topology {
        let hosts_per_leaf = match kind {
            TopologyKind::SingleSwitch => nodes,
            TopologyKind::LeafSpine { leaves, spines } => {
                assert!(leaves > 0 && spines > 0, "empty tier");
                assert!(
                    nodes % leaves == 0,
                    "{nodes} hosts do not divide across {leaves} leaves"
                );
                nodes / leaves
            }
            TopologyKind::FatTree {
                pods,
                leaves_per_pod,
                spines_per_pod,
                core,
            } => {
                assert!(
                    pods > 0 && leaves_per_pod > 0 && spines_per_pod > 0 && core > 0,
                    "empty tier"
                );
                let leaves = pods * leaves_per_pod;
                assert!(
                    nodes % leaves == 0,
                    "{nodes} hosts do not divide across {leaves} fat-tree leaves"
                );
                nodes / leaves
            }
        };
        Topology {
            kind,
            nodes,
            hosts_per_leaf,
        }
    }

    /// Total egress links the fabric must own queues for.
    pub fn n_links(&self) -> usize {
        match self.kind {
            TopologyKind::SingleSwitch => self.nodes,
            // leaf→host (nodes) + leaf→spine + spine→leaf
            TopologyKind::LeafSpine { leaves, spines } => self.nodes + 2 * leaves * spines,
            // edge + leaf↔pod-spine both ways + pod-spine↔core both ways
            TopologyKind::FatTree {
                pods,
                leaves_per_pod,
                spines_per_pod,
                core,
            } => {
                self.nodes
                    + 2 * pods * leaves_per_pod * spines_per_pod
                    + 2 * pods * spines_per_pod * core
            }
        }
    }

    /// Leaf switches in the fabric (0 when single-switch — it has no
    /// leaf tier).
    pub fn n_leaves(&self) -> usize {
        match self.kind {
            TopologyKind::SingleSwitch => 0,
            TopologyKind::LeafSpine { leaves, .. } => leaves,
            TopologyKind::FatTree {
                pods, leaves_per_pod, ..
            } => pods * leaves_per_pod,
        }
    }

    /// Spine switches in the fabric — GLOBAL count in fat-tree mode
    /// (`pods × spines_per_pod`). Fault plans and scenarios derive their
    /// target sets from this instead of pattern-matching the kind.
    pub fn n_spines(&self) -> usize {
        match self.kind {
            TopologyKind::SingleSwitch => 0,
            TopologyKind::LeafSpine { spines, .. } => spines,
            TopologyKind::FatTree {
                pods, spines_per_pod, ..
            } => pods * spines_per_pod,
        }
    }

    /// Core switches (fat-tree only).
    pub fn n_cores(&self) -> usize {
        match self.kind {
            TopologyKind::FatTree { core, .. } => core,
            _ => 0,
        }
    }

    /// Host-to-uplink oversubscription at a leaf: hosts per leaf divided
    /// by its uplink count (1.0 = non-blocking at the leaf tier).
    pub fn oversubscription(&self) -> f64 {
        match self.kind {
            TopologyKind::SingleSwitch => 1.0,
            TopologyKind::LeafSpine { spines, .. } => {
                self.hosts_per_leaf as f64 / spines as f64
            }
            TopologyKind::FatTree { spines_per_pod, .. } => {
                self.hosts_per_leaf as f64 / spines_per_pod as f64
            }
        }
    }

    /// Edge links (switch→host) are the PFC/incast locus and keep their
    /// seed indices: link `n` feeds host `n`.
    pub fn is_edge(&self, link: LinkId) -> bool {
        link < self.nodes
    }

    pub fn host_leaf(&self, node: NodeId) -> usize {
        node / self.hosts_per_leaf
    }

    pub fn host_link(&self, node: NodeId) -> LinkId {
        node
    }

    /// Leaf `l`'s egress toward spine `s`. Bounds-checked: an
    /// out-of-range index would silently alias another leaf's link.
    pub fn up_link(&self, leaf: usize, spine: usize) -> LinkId {
        let TopologyKind::LeafSpine { leaves, spines } = self.kind else {
            unreachable!("up_link in single-switch mode");
        };
        assert!(leaf < leaves && spine < spines, "up_link({leaf},{spine}) out of range");
        self.nodes + leaf * spines + spine
    }

    /// Spine `s`'s egress toward leaf `l`. Bounds-checked like
    /// [`Topology::up_link`].
    pub fn down_link(&self, spine: usize, leaf: usize) -> LinkId {
        let TopologyKind::LeafSpine { leaves, spines } = self.kind else {
            unreachable!("down_link in single-switch mode");
        };
        assert!(leaf < leaves && spine < spines, "down_link({spine},{leaf}) out of range");
        self.nodes + leaves * spines + spine * leaves + leaf
    }

    // ---- fat-tree link layout ----------------------------------------------
    //
    // With P = pods, L = leaves_per_pod, S = spines_per_pod, C = core,
    // global leaf g = pod·L + l, global pod-spine ps = pod·S + s:
    //
    //   [0, nodes)                              leaf → host (edge; LinkId == NodeId)
    //   base1 = nodes          + [g·S + s)      leaf g → its pod spine s   (up1)
    //   base2 = base1 + P·L·S  + [ps·L + l)     pod spine ps → its leaf l  (down1)
    //   base3 = base2 + P·S·L  + [ps·C + c)     pod spine ps → core c      (up2)
    //   base4 = base3 + P·S·C  + [c·P·S + ps)   core c → pod spine ps      (down2)
    //
    // Each constructor below is inverted exactly by `link_dst`
    // (`fat_tree_link_indices_are_a_partition` walks the bijection).

    /// Leaf `leaf` (global) → pod spine `s` (within the leaf's pod).
    pub fn ft_up1(&self, leaf: usize, s: usize) -> LinkId {
        let TopologyKind::FatTree {
            pods,
            leaves_per_pod,
            spines_per_pod,
            ..
        } = self.kind
        else {
            unreachable!("ft_up1 outside fat-tree mode");
        };
        assert!(
            leaf < pods * leaves_per_pod && s < spines_per_pod,
            "ft_up1({leaf},{s}) out of range"
        );
        self.nodes + leaf * spines_per_pod + s
    }

    /// Pod spine `ps` (global) → leaf `l` (within the spine's pod).
    pub fn ft_down1(&self, ps: usize, l: usize) -> LinkId {
        let TopologyKind::FatTree {
            pods,
            leaves_per_pod,
            spines_per_pod,
            ..
        } = self.kind
        else {
            unreachable!("ft_down1 outside fat-tree mode");
        };
        assert!(
            ps < pods * spines_per_pod && l < leaves_per_pod,
            "ft_down1({ps},{l}) out of range"
        );
        self.nodes + pods * leaves_per_pod * spines_per_pod + ps * leaves_per_pod + l
    }

    /// Pod spine `ps` (global) → core `c`.
    pub fn ft_up2(&self, ps: usize, c: usize) -> LinkId {
        let TopologyKind::FatTree {
            pods,
            leaves_per_pod,
            spines_per_pod,
            core,
        } = self.kind
        else {
            unreachable!("ft_up2 outside fat-tree mode");
        };
        assert!(ps < pods * spines_per_pod && c < core, "ft_up2({ps},{c}) out of range");
        self.nodes
            + pods * leaves_per_pod * spines_per_pod
            + pods * spines_per_pod * leaves_per_pod
            + ps * core
            + c
    }

    /// Core `c` → pod spine `ps` (global).
    pub fn ft_down2(&self, c: usize, ps: usize) -> LinkId {
        let TopologyKind::FatTree {
            pods,
            leaves_per_pod,
            spines_per_pod,
            core,
        } = self.kind
        else {
            unreachable!("ft_down2 outside fat-tree mode");
        };
        assert!(ps < pods * spines_per_pod && c < core, "ft_down2({c},{ps}) out of range");
        self.nodes
            + 2 * pods * leaves_per_pod * spines_per_pod
            + pods * spines_per_pod * core
            + c * pods * spines_per_pod
            + ps
    }

    /// The pod a global leaf belongs to (fat-tree).
    pub fn leaf_pod(&self, leaf: usize) -> usize {
        match self.kind {
            TopologyKind::FatTree { leaves_per_pod, .. } => leaf / leaves_per_pod,
            _ => 0,
        }
    }

    /// The pod a global pod-spine belongs to (fat-tree).
    pub fn spine_pod(&self, ps: usize) -> usize {
        match self.kind {
            TopologyKind::FatTree { spines_per_pod, .. } => ps / spines_per_pod,
            _ => 0,
        }
    }

    pub fn link_dst(&self, link: LinkId) -> LinkDst {
        if link < self.nodes {
            return LinkDst::Host(link);
        }
        match self.kind {
            TopologyKind::SingleSwitch => unreachable!("core link in single-switch mode"),
            TopologyKind::LeafSpine { leaves, spines } => {
                let rel = link - self.nodes;
                if rel < leaves * spines {
                    LinkDst::Spine(rel % spines)
                } else {
                    let rel = rel - leaves * spines;
                    LinkDst::Leaf(rel % leaves)
                }
            }
            TopologyKind::FatTree {
                pods,
                leaves_per_pod,
                spines_per_pod,
                core,
            } => {
                let mut rel = link - self.nodes;
                let n_up1 = pods * leaves_per_pod * spines_per_pod;
                if rel < n_up1 {
                    // leaf g → its pod's spine s: global ps = pod·S + s
                    let (g, s) = (rel / spines_per_pod, rel % spines_per_pod);
                    return LinkDst::Spine((g / leaves_per_pod) * spines_per_pod + s);
                }
                rel -= n_up1;
                let n_down1 = pods * spines_per_pod * leaves_per_pod;
                if rel < n_down1 {
                    // pod spine ps → its pod's leaf l: global leaf = pod·L + l
                    let (ps, l) = (rel / leaves_per_pod, rel % leaves_per_pod);
                    return LinkDst::Leaf((ps / spines_per_pod) * leaves_per_pod + l);
                }
                rel -= n_down1;
                let n_up2 = pods * spines_per_pod * core;
                if rel < n_up2 {
                    return LinkDst::Core(rel % core);
                }
                rel -= n_up2;
                debug_assert!(rel < core * pods * spines_per_pod, "link id past the fabric");
                LinkDst::Spine(rel % (pods * spines_per_pod))
            }
        }
    }

    /// Every link touching spine `s` (both directions) — the unit a spine
    /// failure takes down. In fat-tree mode `s` is the GLOBAL pod-spine
    /// index and the set spans both tiers the spine touches (its pod's
    /// leaves below, every core above). Fails fast on a nonexistent spine
    /// rather than letting the bad index alias other links at
    /// fault-fire time.
    pub fn spine_links(&self, spine: usize) -> Vec<LinkId> {
        match self.kind {
            TopologyKind::SingleSwitch => Vec::new(),
            TopologyKind::LeafSpine { leaves, spines } => {
                assert!(spine < spines, "spine {spine} out of range (fabric has {spines})");
                (0..leaves)
                    .flat_map(|l| [self.up_link(l, spine), self.down_link(spine, l)])
                    .collect()
            }
            TopologyKind::FatTree {
                pods,
                leaves_per_pod,
                spines_per_pod,
                core,
            } => {
                let n = pods * spines_per_pod;
                assert!(spine < n, "pod spine {spine} out of range (fabric has {n})");
                let pod = spine / spines_per_pod;
                let s = spine % spines_per_pod;
                let mut links = Vec::with_capacity(2 * (leaves_per_pod + core));
                for l in 0..leaves_per_pod {
                    links.push(self.ft_up1(pod * leaves_per_pod + l, s));
                    links.push(self.ft_down1(spine, l));
                }
                for c in 0..core {
                    links.push(self.ft_up2(spine, c));
                    links.push(self.ft_down2(c, spine));
                }
                links
            }
        }
    }

    /// Every link touching core switch `c` (both directions) — the unit
    /// a core failure takes down (fat-tree only).
    pub fn core_links(&self, c: usize) -> Vec<LinkId> {
        let TopologyKind::FatTree {
            pods,
            spines_per_pod,
            core,
            ..
        } = self.kind
        else {
            return Vec::new();
        };
        assert!(c < core, "core {c} out of range (fabric has {core})");
        (0..pods * spines_per_pod)
            .flat_map(|ps| [self.ft_up2(ps, c), self.ft_down2(c, ps)])
            .collect()
    }

    /// Switch a host's uplink lands on.
    pub fn ingress_switch(&self, src: NodeId) -> SwitchCode {
        match self.kind {
            TopologyKind::SingleSwitch => 0,
            _ => self.host_leaf(src) as SwitchCode,
        }
    }

    pub fn sw_leaf(&self, leaf: usize) -> SwitchCode {
        leaf as SwitchCode
    }

    /// Spine switch code — `spine` is the GLOBAL pod-spine index in
    /// fat-tree mode. Codes: leaves, then spines, then cores.
    pub fn sw_spine(&self, spine: usize) -> SwitchCode {
        match self.kind {
            TopologyKind::SingleSwitch => unreachable!("spine in single-switch mode"),
            TopologyKind::LeafSpine { leaves, .. } => (leaves + spine) as SwitchCode,
            TopologyKind::FatTree { .. } => (self.n_leaves() + spine) as SwitchCode,
        }
    }

    /// Core switch code (fat-tree only).
    pub fn sw_core(&self, c: usize) -> SwitchCode {
        let TopologyKind::FatTree { .. } = self.kind else {
            unreachable!("core switch outside fat-tree mode");
        };
        (self.n_leaves() + self.n_spines() + c) as SwitchCode
    }

    /// Links a cross-fabric (worst-case) path traverses one way — feeds
    /// `CcCtx::hops` and the base-RTT model.
    pub fn path_links(&self) -> u32 {
        match self.kind {
            TopologyKind::SingleSwitch => 2,     // host→ToR→host
            TopologyKind::LeafSpine { .. } => 4, // host→leaf→spine→leaf→host
            // host→leaf→spine→core→spine→leaf→host (cross-pod)
            TopologyKind::FatTree { .. } => 6,
        }
    }

    /// Switch traversals on that worst-case path.
    pub fn path_switches(&self) -> u32 {
        match self.kind {
            TopologyKind::SingleSwitch => 1,
            TopologyKind::LeafSpine { .. } => 3,
            TopologyKind::FatTree { .. } => 5,
        }
    }

    /// Flow label for ECMP hashing: keeps one flow's packets on one path
    /// (no reordering for transports that can't tolerate it) while
    /// spreading distinct QPs across spines.
    pub fn flow_label(pkt: &Packet) -> u64 {
        match &pkt.kind {
            PktKind::Data(h) => (h.dst_qpn as u64) << 32 | h.src_qpn as u64,
            PktKind::Ack(h) => h.dst_qpn as u64,
            PktKind::Nack(h) => h.dst_qpn as u64,
            PktKind::Cnp { dst_qpn }
            | PktKind::Credit { dst_qpn, .. }
            | PktKind::PullReq { dst_qpn, .. } => *dst_qpn as u64,
            // background tenants / control / pause frames: per-pair hashing
            PktKind::Bg | PktKind::Ctrl(_) | PktKind::Pause { .. } => 0,
        }
    }

    /// Deterministic ECMP hash (splitmix64 over the 5-tuple stand-in).
    /// Stable across runs — determinism rides on it.
    pub fn ecmp_hash(src: NodeId, dst: NodeId, label: u64) -> u64 {
        let mut z = (src as u64)
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add((dst as u64) << 32)
            .wrapping_add(label)
            .wrapping_add(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Tier-salted ECMP hash for fat-tree routing: the same flow hashes
    /// independently at the leaf (spine choice) and spine (core choice)
    /// tiers — with the raw hash reused, `hash % S` and `hash % C` would
    /// correlate whenever S and C share factors, collapsing path
    /// diversity. Leaf–spine mode keeps the unsalted hash (one up-level
    /// choice per path, and its grids must reproduce byte-identically).
    pub fn ecmp_hash_tier(src: NodeId, dst: NodeId, label: u64, tier: u64) -> u64 {
        Self::ecmp_hash(src, dst, label ^ tier.wrapping_mul(0xd1b5_4a32_d192_ed03))
    }
}

/// Topology-derived partition map for the conservative parallel DES
/// engine (`sim/cluster.rs`). The cluster is cut along its natural
/// locality seams — one partition per **leaf** in leaf–spine mode, one
/// per **pod** in fat-tree mode (a single switch is one partition) —
/// so that a host, its edge link, and its ingress leaf always live
/// together and only switch→switch hops (which carry ≥ one propagation
/// delay of lookahead) ever cross a partition boundary.
///
/// The cut depends ONLY on the topology: `--cores` picks how many
/// worker threads execute the partitions, never how the cluster is
/// partitioned, so the event schedule — and therefore the merged
/// metrics — is identical for any core count.
#[derive(Clone, Debug)]
pub struct PartitionMap {
    /// Partition count (leaves, pods, or 1).
    pub n_parts: usize,
    /// Owner of each switch code (codes order: leaves, spines, cores).
    /// Spines/cores have no intrinsic home; they round-robin.
    pub switch_part: Vec<u32>,
    /// Owner of each egress link = the owner of its SOURCE switch (the
    /// switch that enqueues onto it); edge link `n` therefore lands in
    /// host `n`'s partition.
    pub link_part: Vec<u32>,
    /// Owner of each host (its leaf's partition). Hosts are contiguous
    /// per partition: partition `p` owns `[p·nodes/n_parts, (p+1)·…)`.
    pub node_part: Vec<u32>,
}

impl PartitionMap {
    pub fn new(topo: &Topology) -> PartitionMap {
        let n_parts = match topo.kind {
            TopologyKind::SingleSwitch => 1,
            TopologyKind::LeafSpine { leaves, .. } => leaves,
            TopologyKind::FatTree { pods, .. } => pods,
        };
        let node_part: Vec<u32> = (0..topo.nodes)
            .map(|n| match topo.kind {
                TopologyKind::SingleSwitch => 0,
                TopologyKind::LeafSpine { .. } => topo.host_leaf(n) as u32,
                TopologyKind::FatTree { .. } => topo.leaf_pod(topo.host_leaf(n)) as u32,
            })
            .collect();
        let n_sw = (topo.n_leaves() + topo.n_spines() + topo.n_cores()).max(1);
        let mut switch_part = vec![0u32; n_sw];
        match topo.kind {
            TopologyKind::SingleSwitch => {}
            TopologyKind::LeafSpine { leaves, spines } => {
                for l in 0..leaves {
                    switch_part[l] = l as u32;
                }
                for s in 0..spines {
                    switch_part[leaves + s] = (s % n_parts) as u32;
                }
            }
            TopologyKind::FatTree { pods, core, .. } => {
                for g in 0..topo.n_leaves() {
                    switch_part[g] = topo.leaf_pod(g) as u32;
                }
                for ps in 0..topo.n_spines() {
                    switch_part[topo.n_leaves() + ps] = topo.spine_pod(ps) as u32;
                }
                for c in 0..core {
                    switch_part[topo.n_leaves() + topo.n_spines() + c] = (c % pods) as u32;
                }
            }
        }
        let link_part: Vec<u32> = (0..topo.n_links())
            .map(|link| {
                if link < topo.nodes {
                    // edge link n: source = host n's leaf
                    return node_part[link];
                }
                match topo.kind {
                    TopologyKind::SingleSwitch => 0,
                    TopologyKind::LeafSpine { leaves, spines } => {
                        let rel = link - topo.nodes;
                        if rel < leaves * spines {
                            switch_part[rel / spines] // source: leaf
                        } else {
                            let s = (rel - leaves * spines) / leaves;
                            switch_part[leaves + s] // source: spine
                        }
                    }
                    TopologyKind::FatTree {
                        pods,
                        leaves_per_pod,
                        spines_per_pod,
                        core,
                    } => {
                        let leaves = pods * leaves_per_pod;
                        let spines = pods * spines_per_pod;
                        let mut rel = link - topo.nodes;
                        if rel < leaves * spines_per_pod {
                            return switch_part[rel / spines_per_pod]; // up1: leaf
                        }
                        rel -= leaves * spines_per_pod;
                        if rel < spines * leaves_per_pod {
                            return switch_part[leaves + rel / leaves_per_pod]; // down1: spine
                        }
                        rel -= spines * leaves_per_pod;
                        if rel < spines * core {
                            return switch_part[leaves + rel / core]; // up2: spine
                        }
                        rel -= spines * core;
                        switch_part[leaves + spines + rel / spines] // down2: core
                    }
                }
            })
            .collect();
        PartitionMap {
            n_parts,
            switch_part,
            link_part,
            node_part,
        }
    }

    /// Hosts per partition (hosts divide evenly across leaves/pods).
    pub fn hosts_per_part(&self) -> usize {
        self.node_part.len() / self.n_parts
    }

    /// First host owned by partition `p` (hosts are contiguous).
    pub fn host_base(&self, p: usize) -> NodeId {
        p * self.hosts_per_part()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ls(nodes: usize, leaves: usize, spines: usize) -> Topology {
        Topology::new(TopologyKind::LeafSpine { leaves, spines }, nodes)
    }

    #[test]
    fn single_switch_degenerates_to_seed_indices() {
        let t = Topology::new(TopologyKind::SingleSwitch, 8);
        assert_eq!(t.n_links(), 8);
        assert_eq!(t.host_link(5), 5);
        assert!(t.is_edge(7));
        assert_eq!(t.link_dst(3), LinkDst::Host(3));
        assert_eq!(t.ingress_switch(6), 0);
        assert_eq!(t.path_links(), 2);
        assert_eq!(t.path_switches(), 1);
        assert!(!t.kind.is_multitier());
    }

    #[test]
    fn link_indices_are_a_partition() {
        let t = ls(8, 2, 3);
        assert_eq!(t.hosts_per_leaf, 4);
        assert_eq!(t.n_links(), 8 + 2 * 2 * 3);
        // every link id maps to exactly one (kind, endpoints) and the
        // constructors invert link_dst
        let mut seen = vec![false; t.n_links()];
        for n in 0..8 {
            let l = t.host_link(n);
            assert_eq!(t.link_dst(l), LinkDst::Host(n));
            assert!(!seen[l]);
            seen[l] = true;
        }
        for leaf in 0..2 {
            for spine in 0..3 {
                let up = t.up_link(leaf, spine);
                assert_eq!(t.link_dst(up), LinkDst::Spine(spine));
                assert!(!seen[up], "up_link collision at {up}");
                seen[up] = true;
                let down = t.down_link(spine, leaf);
                assert_eq!(t.link_dst(down), LinkDst::Leaf(leaf));
                assert!(!seen[down], "down_link collision at {down}");
                seen[down] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "unreferenced link ids");
    }

    #[test]
    fn hosts_map_to_leaves_in_blocks() {
        let t = ls(8, 2, 2);
        assert_eq!(t.host_leaf(0), 0);
        assert_eq!(t.host_leaf(3), 0);
        assert_eq!(t.host_leaf(4), 1);
        assert_eq!(t.host_leaf(7), 1);
        assert_eq!(t.ingress_switch(5), t.sw_leaf(1));
        assert_eq!(t.path_links(), 4);
        assert_eq!(t.path_switches(), 3);
    }

    #[test]
    fn spine_links_cover_both_directions() {
        let t = ls(4, 2, 2);
        let links = t.spine_links(1);
        assert_eq!(links.len(), 4); // 2 leaves × {up, down}
        assert!(links.contains(&t.up_link(0, 1)));
        assert!(links.contains(&t.up_link(1, 1)));
        assert!(links.contains(&t.down_link(1, 0)));
        assert!(links.contains(&t.down_link(1, 1)));
        // and none of spine 0's
        assert!(!links.contains(&t.up_link(0, 0)));
    }

    #[test]
    fn ecmp_hash_is_stable_and_spreads() {
        // stability: the same tuple always hashes identically
        assert_eq!(
            Topology::ecmp_hash(1, 2, 77),
            Topology::ecmp_hash(1, 2, 77)
        );
        // spread: distinct labels land on both of 2 spines eventually
        let hits: Vec<usize> = (0..32)
            .map(|label| (Topology::ecmp_hash(0, 5, label) % 2) as usize)
            .collect();
        assert!(hits.contains(&0) && hits.contains(&1), "degenerate hash");
    }

    #[test]
    #[should_panic]
    fn nodes_must_divide_leaves() {
        ls(7, 2, 2);
    }

    // ---- fat-tree -----------------------------------------------------------

    fn ft(nodes: usize, pods: usize, l: usize, s: usize, c: usize) -> Topology {
        Topology::new(
            TopologyKind::FatTree {
                pods,
                leaves_per_pod: l,
                spines_per_pod: s,
                core: c,
            },
            nodes,
        )
    }

    #[test]
    fn fat_tree_counts_and_edges_keep_seed_indices() {
        let t = ft(16, 2, 2, 2, 2);
        assert!(t.kind.is_multitier());
        assert_eq!(t.kind.name(), "fat-tree");
        assert_eq!(t.hosts_per_leaf, 4);
        assert_eq!(t.n_leaves(), 4);
        assert_eq!(t.n_spines(), 4);
        assert_eq!(t.n_cores(), 2);
        // edge LinkId == NodeId, exactly like the other modes
        for n in 0..16 {
            assert_eq!(t.host_link(n), n);
            assert!(t.is_edge(n));
            assert_eq!(t.link_dst(n), LinkDst::Host(n));
        }
        // 16 edge + 2·(2·2·2) up1/down1 + 2·(2·2·2) up2/down2
        assert_eq!(t.n_links(), 16 + 16 + 16);
        assert_eq!(t.path_links(), 6);
        assert_eq!(t.path_switches(), 5);
        // 4:2 hosts:uplinks per leaf = 2:1 oversubscribed
        assert!((t.oversubscription() - 2.0).abs() < 1e-12);
    }

    /// The fat-tree bijection: every link id belongs to exactly one
    /// constructor and `link_dst` inverts each of them — the same
    /// partition contract the leaf–spine layout is pinned by.
    #[test]
    fn fat_tree_link_indices_are_a_partition() {
        let t = ft(24, 2, 3, 2, 3); // deliberately asymmetric tiers
        let (pods, lpp, spp, core) = (2, 3, 2, 3);
        let mut seen = vec![false; t.n_links()];
        for n in 0..24 {
            let l = t.host_link(n);
            assert_eq!(t.link_dst(l), LinkDst::Host(n));
            assert!(!seen[l]);
            seen[l] = true;
        }
        for g in 0..pods * lpp {
            for s in 0..spp {
                let up = t.ft_up1(g, s);
                let ps_global = t.leaf_pod(g) * spp + s;
                assert_eq!(t.link_dst(up), LinkDst::Spine(ps_global));
                assert!(!seen[up], "ft_up1 collision at {up}");
                seen[up] = true;
            }
        }
        for ps in 0..pods * spp {
            for l in 0..lpp {
                let down = t.ft_down1(ps, l);
                let leaf_global = t.spine_pod(ps) * lpp + l;
                assert_eq!(t.link_dst(down), LinkDst::Leaf(leaf_global));
                assert!(!seen[down], "ft_down1 collision at {down}");
                seen[down] = true;
            }
            for c in 0..core {
                let up2 = t.ft_up2(ps, c);
                assert_eq!(t.link_dst(up2), LinkDst::Core(c));
                assert!(!seen[up2], "ft_up2 collision at {up2}");
                seen[up2] = true;
                let down2 = t.ft_down2(c, ps);
                assert_eq!(t.link_dst(down2), LinkDst::Spine(ps));
                assert!(!seen[down2], "ft_down2 collision at {down2}");
                seen[down2] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "unreferenced fat-tree link ids");
    }

    #[test]
    fn fat_tree_switch_codes_are_contiguous() {
        let t = ft(16, 2, 2, 2, 2);
        assert_eq!(t.sw_leaf(3), 3);
        assert_eq!(t.sw_spine(0), 4);
        assert_eq!(t.sw_spine(3), 7);
        assert_eq!(t.sw_core(0), 8);
        assert_eq!(t.sw_core(1), 9);
        assert_eq!(t.ingress_switch(15), t.sw_leaf(3));
        assert_eq!(t.leaf_pod(3), 1);
        assert_eq!(t.spine_pod(2), 1);
    }

    #[test]
    fn fat_tree_spine_and_core_links_cover_both_tiers() {
        let t = ft(16, 2, 2, 2, 2);
        // pod spine 2 = pod 1's spine 0: 2 leaves × 2 dirs + 2 cores × 2 dirs
        let links = t.spine_links(2);
        assert_eq!(links.len(), 8);
        assert!(links.contains(&t.ft_up1(2, 0))); // pod 1 leaf 0 up
        assert!(links.contains(&t.ft_down1(2, 1)));
        assert!(links.contains(&t.ft_up2(2, 1)));
        assert!(links.contains(&t.ft_down2(0, 2)));
        // and none of pod 0's
        assert!(!links.contains(&t.ft_up1(0, 0)));
        let cl = t.core_links(1);
        assert_eq!(cl.len(), 2 * t.n_spines());
        assert!(cl.contains(&t.ft_up2(3, 1)));
        assert!(cl.contains(&t.ft_down2(1, 0)));
    }

    #[test]
    fn tier_salted_hash_decorrelates_levels() {
        // same flow, different tier salts → the two choices must not be
        // the same function of the tuple
        let mut differs = false;
        for label in 0..32u64 {
            let a = Topology::ecmp_hash_tier(0, 9, label, 1) % 4;
            let b = Topology::ecmp_hash_tier(0, 9, label, 2) % 4;
            differs |= a != b;
        }
        assert!(differs, "tier salt has no effect");
        // tier 0 keeps whatever the caller passes deterministic
        assert_eq!(
            Topology::ecmp_hash_tier(1, 2, 7, 1),
            Topology::ecmp_hash_tier(1, 2, 7, 1)
        );
    }

    #[test]
    #[should_panic]
    fn fat_tree_nodes_must_divide_leaves() {
        ft(10, 2, 2, 2, 2);
    }

    // ---- partition map ------------------------------------------------------

    /// Every link's owner is its SOURCE switch's partition — the enqueue
    /// side — so a partition only ever mutates ports it owns.
    fn assert_links_follow_source(t: &Topology, pm: &PartitionMap) {
        for n in 0..t.nodes {
            // edge link n: enqueued by host n's leaf
            assert_eq!(pm.link_part[n], pm.node_part[n], "edge link {n}");
            assert_eq!(
                pm.node_part[n],
                pm.switch_part[t.ingress_switch(n) as usize],
                "host {n} not co-located with its leaf"
            );
        }
    }

    #[test]
    fn partition_map_single_switch_is_one_partition() {
        let t = Topology::new(TopologyKind::SingleSwitch, 8);
        let pm = PartitionMap::new(&t);
        assert_eq!(pm.n_parts, 1);
        assert!(pm.link_part.iter().all(|&p| p == 0));
        assert!(pm.node_part.iter().all(|&p| p == 0));
        assert_eq!(pm.hosts_per_part(), 8);
    }

    #[test]
    fn partition_map_leaf_spine_cuts_by_leaf() {
        let t = ls(8, 2, 3);
        let pm = PartitionMap::new(&t);
        assert_eq!(pm.n_parts, 2);
        assert_links_follow_source(&t, &pm);
        for leaf in 0..2 {
            for spine in 0..3 {
                assert_eq!(pm.link_part[t.up_link(leaf, spine)], leaf as u32);
                assert_eq!(
                    pm.link_part[t.down_link(spine, leaf)],
                    pm.switch_part[t.sw_spine(spine) as usize]
                );
            }
        }
        // spines round-robin across partitions
        assert_eq!(pm.switch_part[t.sw_spine(0) as usize], 0);
        assert_eq!(pm.switch_part[t.sw_spine(1) as usize], 1);
        assert_eq!(pm.switch_part[t.sw_spine(2) as usize], 0);
        // hosts contiguous per partition
        assert_eq!(pm.hosts_per_part(), 4);
        assert_eq!(pm.host_base(1), 4);
        assert_eq!(&pm.node_part[..], &[0, 0, 0, 0, 1, 1, 1, 1]);
    }

    #[test]
    fn partition_map_fat_tree_cuts_by_pod() {
        let t = ft(24, 2, 3, 2, 3);
        let (pods, lpp, spp, core) = (2usize, 3usize, 2usize, 3usize);
        let pm = PartitionMap::new(&t);
        assert_eq!(pm.n_parts, pods);
        assert_links_follow_source(&t, &pm);
        for g in 0..pods * lpp {
            let pod = t.leaf_pod(g) as u32;
            assert_eq!(pm.switch_part[t.sw_leaf(g) as usize], pod);
            for s in 0..spp {
                assert_eq!(pm.link_part[t.ft_up1(g, s)], pod, "up1 source leaf {g}");
            }
        }
        for ps in 0..pods * spp {
            let pod = t.spine_pod(ps) as u32;
            assert_eq!(pm.switch_part[t.sw_spine(ps) as usize], pod);
            for l in 0..lpp {
                assert_eq!(pm.link_part[t.ft_down1(ps, l)], pod, "down1 source ps {ps}");
            }
            for c in 0..core {
                assert_eq!(pm.link_part[t.ft_up2(ps, c)], pod, "up2 source ps {ps}");
                assert_eq!(
                    pm.link_part[t.ft_down2(c, ps)],
                    pm.switch_part[t.sw_core(c) as usize],
                    "down2 source core {c}"
                );
            }
        }
        // cores round-robin across pods
        assert_eq!(pm.switch_part[t.sw_core(0) as usize], 0);
        assert_eq!(pm.switch_part[t.sw_core(1) as usize], 1);
        assert_eq!(pm.switch_part[t.sw_core(2) as usize], 0);
        assert_eq!(pm.hosts_per_part(), 12);
    }
}
