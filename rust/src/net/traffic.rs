//! Background (cross) traffic generator.
//!
//! §5.1.1: "To emulate realistic multi-tenant conditions, we introduce
//! controlled background traffic that reflects RDMA network behavior
//! reported in prior works." We model the standard datacenter workload
//! shape: Poisson flow arrivals with heavy-tailed (Pareto) flow sizes,
//! targeting uniformly random destination ports. Each active flow injects
//! MTU packets at the port until drained. The generator produces *injection
//! events* that the DES turns into queue occupancy — so background traffic
//! competes with collective traffic for buffers, triggers ECN marks, drops,
//! and (for RoCE) PFC pauses.
//!
//! Load fidelity: flow sizes are truncated (`max_flow_bytes` cap, MTU
//! floor), so the arrival pacing is derived from the mean of the
//! *truncated* distribution — otherwise the cap silently skews realized
//! load below `cfg.load` (a ~19% deficit at the defaults), and the
//! injected-byte ledger books exactly the bytes the flow will inject.

use crate::util::prng::Pcg64;
use crate::verbs::NodeId;

#[derive(Clone, Debug)]
pub struct BgTrafficCfg {
    /// Target average load as a fraction of per-link capacity (0 = off).
    pub load: f64,
    /// Mean flow size, bytes (Pareto with shape 1.2 around this mean,
    /// before truncation).
    pub mean_flow_bytes: f64,
    /// Pareto shape (>1; lower = heavier tail).
    pub pareto_shape: f64,
    /// MTU used for background packets.
    pub mtu: usize,
    /// Hard cap on a single flow (heavy-tail truncation), bytes.
    pub max_flow_bytes: f64,
}

impl Default for BgTrafficCfg {
    fn default() -> Self {
        BgTrafficCfg {
            load: 0.2,
            mean_flow_bytes: 256.0 * 1024.0,
            pareto_shape: 1.2,
            mtu: 1500,
            max_flow_bytes: 64.0 * 1024.0 * 1024.0,
        }
    }
}

impl BgTrafficCfg {
    /// Pareto scale xₘ for the configured (untruncated) mean:
    /// mean = xₘ·a/(a−1).
    fn pareto_xm(&self) -> f64 {
        self.mean_flow_bytes * (self.pareto_shape - 1.0) / self.pareto_shape
    }

    /// Mean of the flow size actually injected, E[max(mtu, min(X, C))]
    /// for X ~ Pareto(xₘ, a), C = `max_flow_bytes` — closed form, so the
    /// arrival pacing can hit `load` exactly in expectation instead of
    /// undershooting by the truncated tail mass.
    pub fn effective_mean_flow_bytes(&self) -> f64 {
        let a = self.pareto_shape;
        let xm = self.pareto_xm();
        let c = self.max_flow_bytes.max(xm);
        let m = (self.mtu as f64).min(c);
        // split at L = max(xm, m): below L the draw is floored to m (only
        // possible when m > xm), above it min(X, C) integrates in closed
        // form: ∫ₗᶜ x·f(x) dx + C·P(X > C)
        let l = xm.max(m);
        let mut e = (a * xm.powf(a) / (a - 1.0)) * (l.powf(1.0 - a) - c.powf(1.0 - a))
            + c * (xm / c).powf(a);
        if m > xm {
            e += m * (1.0 - (xm / m).powf(a));
        }
        e
    }
}

/// One queued injection: `bytes` to be fed into `port`'s downlink starting
/// at `start_ns`, paced at the flow rate.
#[derive(Clone, Copy, Debug)]
pub struct BgFlow {
    pub port: NodeId,
    pub bytes: usize,
    pub start_ns: u64,
}

#[derive(Debug)]
pub struct BgTraffic {
    pub cfg: BgTrafficCfg,
    nodes: usize,
    link_bytes_per_ns: f64,
    /// Cached `cfg.effective_mean_flow_bytes()` — consulted per arrival.
    eff_mean_flow_bytes: f64,
    rng: Pcg64,
    /// Next flow arrival time, ns.
    pub next_arrival_ns: u64,
    pub flows_started: u64,
    pub bytes_injected: u64,
}

impl BgTraffic {
    pub fn new(cfg: BgTrafficCfg, nodes: usize, link_gbps: f64, rng: Pcg64) -> BgTraffic {
        let eff_mean_flow_bytes = cfg.effective_mean_flow_bytes();
        let mut t = BgTraffic {
            cfg,
            nodes,
            link_bytes_per_ns: link_gbps / 8.0,
            eff_mean_flow_bytes,
            rng,
            next_arrival_ns: u64::MAX,
            flows_started: 0,
            bytes_injected: 0,
        };
        if t.enabled() {
            t.next_arrival_ns = t.draw_interarrival(0);
        }
        t
    }

    pub fn enabled(&self) -> bool {
        self.cfg.load > 0.0
    }

    /// Mean interarrival so that `nodes * E[flow bytes] / interarrival`
    /// equals `load * capacity` aggregated over ports — using the
    /// truncated-distribution mean, since that is what gets injected.
    fn mean_interarrival_ns(&self) -> f64 {
        let agg_capacity = self.link_bytes_per_ns * self.nodes as f64; // bytes/ns
        let target_rate = self.cfg.load * agg_capacity; // bytes/ns
        self.eff_mean_flow_bytes / target_rate
    }

    fn draw_interarrival(&mut self, now: u64) -> u64 {
        let mean = self.mean_interarrival_ns();
        now + self.rng.exponential(1.0 / mean).ceil() as u64 + 1
    }

    /// Draw the next flow (called by the engine when `next_arrival_ns`
    /// fires); advances the arrival clock. The flow is sized FIRST
    /// (truncated, MTU-floored) and only then booked — the injected-byte
    /// ledger must see the bytes the flow will actually inject, not the
    /// pre-clamp draw.
    pub fn next_flow(&mut self, now: u64) -> BgFlow {
        let a = self.cfg.pareto_shape;
        let xm = self.cfg.pareto_xm();
        let bytes = (self.rng.pareto(xm, a).min(self.cfg.max_flow_bytes) as usize)
            .max(self.cfg.mtu);
        let port = self.rng.index(self.nodes);
        self.flows_started += 1;
        self.bytes_injected += bytes as u64;
        self.next_arrival_ns = self.draw_interarrival(now);
        BgFlow {
            port,
            bytes,
            start_ns: now,
        }
    }

    /// Split a flow into paced packet injections: returns (offset_ns, size)
    /// pairs. Flows are paced at half line rate (they traverse other links
    /// too), which spreads their queue pressure over time.
    pub fn packetize(&self, flow: &BgFlow) -> Vec<(u64, usize)> {
        let mut out = Vec::new();
        let pace_bpns = self.link_bytes_per_ns * 0.5;
        let mut off_bytes = 0usize;
        while off_bytes < flow.bytes {
            let sz = self.cfg.mtu.min(flow.bytes - off_bytes);
            let t = (off_bytes as f64 / pace_bpns) as u64;
            out.push((t, sz));
            off_bytes += sz;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_when_zero_load() {
        let t = BgTraffic::new(
            BgTrafficCfg {
                load: 0.0,
                ..Default::default()
            },
            8,
            25.0,
            Pcg64::seeded(1),
        );
        assert!(!t.enabled());
    }

    #[test]
    fn arrival_rate_roughly_matches_load() {
        let mut t = BgTraffic::new(
            BgTrafficCfg {
                load: 0.3,
                ..Default::default()
            },
            8,
            25.0,
            Pcg64::seeded(2),
        );
        // simulate 100 ms of arrivals (the Pareto tail needs a few
        // thousand flows before the realized mean settles)
        let horizon = 100_000_000u64;
        let mut now = t.next_arrival_ns;
        let mut bytes = 0u64;
        while now < horizon {
            let f = t.next_flow(now);
            bytes += f.bytes as u64;
            now = t.next_arrival_ns;
        }
        let capacity = 25.0 / 8.0 * 8.0 * horizon as f64; // bytes over horizon, all ports
        let load = bytes as f64 / capacity;
        assert!(
            (load - 0.3).abs() < 0.15,
            "achieved load {load} target 0.3"
        );
    }

    /// Satellite regression (fails pre-fix, two ways): (a) the 64 MiB
    /// Pareto cap removed ~19% of the configured mean from the realized
    /// load because pacing used the UNtruncated mean; (b) `bytes_injected`
    /// booked the pre-clamp draw, so the ledger disagreed with the flows
    /// actually emitted. Post-fix, realized injected load tracks the
    /// target within 10% over a long horizon and the ledger is exact.
    #[test]
    fn realized_load_tracks_target_within_10pct() {
        for &target in &[0.2, 0.5] {
            let mut t = BgTraffic::new(
                BgTrafficCfg {
                    load: target,
                    ..Default::default()
                },
                8,
                25.0,
                Pcg64::seeded(42),
            );
            let horizon = 2_000_000_000u64; // 2 s — tame the heavy tail
            let mut now = t.next_arrival_ns;
            let mut flow_bytes = 0u64;
            while now < horizon {
                let f = t.next_flow(now);
                flow_bytes += f.bytes as u64;
                now = t.next_arrival_ns;
            }
            // ledger must equal the bytes handed out as flows
            assert_eq!(flow_bytes, t.bytes_injected, "ledger drifted from flows");
            let capacity = 25.0 / 8.0 * 8.0 * horizon as f64;
            let load = t.bytes_injected as f64 / capacity;
            assert!(
                (load - target).abs() / target < 0.10,
                "realized load {load:.4} vs target {target} (>10% off)"
            );
        }
    }

    /// The closed-form truncated mean the pacing relies on, pinned
    /// against a Monte-Carlo estimate.
    #[test]
    fn effective_mean_matches_monte_carlo() {
        let cfg = BgTrafficCfg::default();
        let analytic = cfg.effective_mean_flow_bytes();
        // the cap bites: effective mean is strictly below the configured
        assert!(analytic < cfg.mean_flow_bytes);
        let mut rng = Pcg64::seeded(9);
        let xm = cfg.pareto_xm();
        let n = 400_000;
        let mc: f64 = (0..n)
            .map(|_| {
                rng.pareto(xm, cfg.pareto_shape)
                    .min(cfg.max_flow_bytes)
                    .max(cfg.mtu as f64)
            })
            .sum::<f64>()
            / n as f64;
        assert!(
            (mc - analytic).abs() / analytic < 0.05,
            "analytic {analytic:.0} vs MC {mc:.0}"
        );
        // with an effectively-infinite cap the truncated mean converges
        // toward the configured mean (slowly — the a = 1.2 tail leaves
        // ~0.2% of the mass beyond even 1e18)
        let wide = BgTrafficCfg {
            max_flow_bytes: 1e18,
            ..Default::default()
        };
        let e = wide.effective_mean_flow_bytes();
        assert!(
            (e - wide.mean_flow_bytes).abs() / wide.mean_flow_bytes < 5e-3,
            "uncapped effective mean {e} vs {}",
            wide.mean_flow_bytes
        );
        assert!(e < wide.mean_flow_bytes, "truncation can only lower the mean");
    }

    #[test]
    fn packetize_covers_flow() {
        let t = BgTraffic::new(BgTrafficCfg::default(), 4, 25.0, Pcg64::seeded(3));
        let flow = BgFlow {
            port: 0,
            bytes: 4000,
            start_ns: 0,
        };
        let pkts = t.packetize(&flow);
        let total: usize = pkts.iter().map(|(_, s)| s).sum();
        assert_eq!(total, 4000);
        // offsets strictly increasing
        for w in pkts.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
    }

    #[test]
    fn flow_sizes_heavy_tailed() {
        let mut t = BgTraffic::new(BgTrafficCfg::default(), 8, 25.0, Pcg64::seeded(4));
        let sizes: Vec<usize> = (0..2000).map(|i| t.next_flow(i * 1000).bytes).collect();
        let mean = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
        let max = *sizes.iter().max().unwrap() as f64;
        // heavy tail: max far above mean
        assert!(max > 5.0 * mean, "max={max} mean={mean}");
        // truncation holds
        assert!(max <= 64.0 * 1024.0 * 1024.0);
    }
}
