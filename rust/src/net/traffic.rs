//! Background (cross) traffic generator.
//!
//! §5.1.1: "To emulate realistic multi-tenant conditions, we introduce
//! controlled background traffic that reflects RDMA network behavior
//! reported in prior works." We model the standard datacenter workload
//! shape: Poisson flow arrivals with heavy-tailed (Pareto) flow sizes,
//! targeting uniformly random destination ports. Each active flow injects
//! MTU packets at the port until drained. The generator produces *injection
//! events* that the DES turns into queue occupancy — so background traffic
//! competes with collective traffic for buffers, triggers ECN marks, drops,
//! and (for RoCE) PFC pauses.

use crate::util::prng::Pcg64;
use crate::verbs::NodeId;

#[derive(Clone, Debug)]
pub struct BgTrafficCfg {
    /// Target average load as a fraction of per-link capacity (0 = off).
    pub load: f64,
    /// Mean flow size, bytes (Pareto with shape 1.2 around this mean).
    pub mean_flow_bytes: f64,
    /// Pareto shape (>1; lower = heavier tail).
    pub pareto_shape: f64,
    /// MTU used for background packets.
    pub mtu: usize,
}

impl Default for BgTrafficCfg {
    fn default() -> Self {
        BgTrafficCfg {
            load: 0.2,
            mean_flow_bytes: 256.0 * 1024.0,
            pareto_shape: 1.2,
            mtu: 1500,
        }
    }
}

/// One queued injection: `bytes` to be fed into `port`'s downlink starting
/// at `start_ns`, paced at the flow rate.
#[derive(Clone, Copy, Debug)]
pub struct BgFlow {
    pub port: NodeId,
    pub bytes: usize,
    pub start_ns: u64,
}

#[derive(Debug)]
pub struct BgTraffic {
    pub cfg: BgTrafficCfg,
    nodes: usize,
    link_bytes_per_ns: f64,
    rng: Pcg64,
    /// Next flow arrival time, ns.
    pub next_arrival_ns: u64,
    pub flows_started: u64,
    pub bytes_injected: u64,
}

impl BgTraffic {
    pub fn new(cfg: BgTrafficCfg, nodes: usize, link_gbps: f64, rng: Pcg64) -> BgTraffic {
        let mut t = BgTraffic {
            cfg,
            nodes,
            link_bytes_per_ns: link_gbps / 8.0,
            rng,
            next_arrival_ns: u64::MAX,
            flows_started: 0,
            bytes_injected: 0,
        };
        if t.enabled() {
            t.next_arrival_ns = t.draw_interarrival(0);
        }
        t
    }

    pub fn enabled(&self) -> bool {
        self.cfg.load > 0.0
    }

    /// Mean interarrival so that `nodes * mean_flow_bytes / interarrival`
    /// equals `load * capacity` aggregated over ports.
    fn mean_interarrival_ns(&self) -> f64 {
        let agg_capacity = self.link_bytes_per_ns * self.nodes as f64; // bytes/ns
        let target_rate = self.cfg.load * agg_capacity; // bytes/ns
        self.cfg.mean_flow_bytes / target_rate
    }

    fn draw_interarrival(&mut self, now: u64) -> u64 {
        let mean = self.mean_interarrival_ns();
        now + self.rng.exponential(1.0 / mean).ceil() as u64 + 1
    }

    /// Draw the next flow (called by the engine when `next_arrival_ns`
    /// fires); advances the arrival clock.
    pub fn next_flow(&mut self, now: u64) -> BgFlow {
        // Pareto sized flow with the configured mean: mean = xm*a/(a-1)
        let a = self.cfg.pareto_shape;
        let xm = self.cfg.mean_flow_bytes * (a - 1.0) / a;
        let bytes = self.rng.pareto(xm, a).min(64.0 * 1024.0 * 1024.0) as usize;
        let port = self.rng.index(self.nodes);
        self.flows_started += 1;
        self.bytes_injected += bytes as u64;
        self.next_arrival_ns = self.draw_interarrival(now);
        BgFlow {
            port,
            bytes: bytes.max(self.cfg.mtu),
            start_ns: now,
        }
    }

    /// Split a flow into paced packet injections: returns (offset_ns, size)
    /// pairs. Flows are paced at half line rate (they traverse other links
    /// too), which spreads their queue pressure over time.
    pub fn packetize(&self, flow: &BgFlow) -> Vec<(u64, usize)> {
        let mut out = Vec::new();
        let pace_bpns = self.link_bytes_per_ns * 0.5;
        let mut off_bytes = 0usize;
        while off_bytes < flow.bytes {
            let sz = self.cfg.mtu.min(flow.bytes - off_bytes);
            let t = (off_bytes as f64 / pace_bpns) as u64;
            out.push((t, sz));
            off_bytes += sz;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_when_zero_load() {
        let t = BgTraffic::new(
            BgTrafficCfg {
                load: 0.0,
                ..Default::default()
            },
            8,
            25.0,
            Pcg64::seeded(1),
        );
        assert!(!t.enabled());
    }

    #[test]
    fn arrival_rate_roughly_matches_load() {
        let mut t = BgTraffic::new(
            BgTrafficCfg {
                load: 0.3,
                ..Default::default()
            },
            8,
            25.0,
            Pcg64::seeded(2),
        );
        // simulate 10 ms of arrivals
        let horizon = 10_000_000u64;
        let mut now = t.next_arrival_ns;
        let mut bytes = 0u64;
        while now < horizon {
            let f = t.next_flow(now);
            bytes += f.bytes as u64;
            now = t.next_arrival_ns;
        }
        let capacity = 25.0 / 8.0 * 8.0 * horizon as f64; // bytes over horizon, all ports
        let load = bytes as f64 / capacity;
        assert!(
            (load - 0.3).abs() < 0.15,
            "achieved load {load} target 0.3"
        );
    }

    #[test]
    fn packetize_covers_flow() {
        let t = BgTraffic::new(BgTrafficCfg::default(), 4, 25.0, Pcg64::seeded(3));
        let flow = BgFlow {
            port: 0,
            bytes: 4000,
            start_ns: 0,
        };
        let pkts = t.packetize(&flow);
        let total: usize = pkts.iter().map(|(_, s)| s).sum();
        assert_eq!(total, 4000);
        // offsets strictly increasing
        for w in pkts.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
    }

    #[test]
    fn flow_sizes_heavy_tailed() {
        let mut t = BgTraffic::new(BgTrafficCfg::default(), 8, 25.0, Pcg64::seeded(4));
        let sizes: Vec<usize> = (0..2000).map(|i| t.next_flow(i * 1000).bytes).collect();
        let mean = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
        let max = *sizes.iter().max().unwrap() as f64;
        // heavy tail: max far above mean
        assert!(max > 5.0 * mean, "max={max} mean={mean}");
    }
}
