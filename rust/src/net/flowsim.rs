//! Hybrid packet/flow fidelity engine for cluster-scale sweeps.
//!
//! Full packet DES ([`crate::sim::cluster`]) is the reference model, but
//! at 1k–10k ranks a single all-reduce iteration pushes hundreds of
//! millions of packet events — far past what a figure grid can afford.
//! The paper's tails, though, are *decided* in a few places (incast
//! edges, faulted links, sprayed last hops); everywhere else long bulk
//! flows behave like fluids. This module implements that split:
//!
//! * **Flow fidelity** — a max-min fair fluid allocation over the link
//!   capacities, re-solved on flow arrival, departure, and fault events
//!   (progressive water-filling: repeatedly freeze the most-contended
//!   link's flows at its fair share `remaining_cap / unfrozen_flows`).
//!   A flow's completion is `remaining / rate` ahead of the last solve,
//!   plus the path's base latency.
//! * **Packet fidelity** — MTU-granular store-and-forward: packets are
//!   paced at the flow's solved fair rate and each packet walks its
//!   path's link *horizons* arithmetically (`depart = max(arrive,
//!   free_at) + ser`; `free_at = depart`), so queueing delay — the tail
//!   — emerges per packet without per-hop events. Down links drop the
//!   packet (retransmitted after an RTO), exactly the blackhole window
//!   the packet engine models.
//! * **[`FidelityPolicy`]** decides per flow at arrival: everything
//!   packet (reference), everything fluid (fastest), or hybrid — packet
//!   exactly where tails are decided (flows below the bulk threshold,
//!   paths touching a designated or faulted link, destinations whose
//!   edge fan-in crossed the incast threshold).
//! * **CC coupling** — when [`FlowSim::enable_cc`] is on, every flow
//!   owns a real [`crate::cc::CongestionControl`] instance behind the
//!   same [`RateAuthority`] seam the packet engine's driver uses, and
//!   the water-fill caps each flow at `min(fair_share, cc_rate)`.
//!   Signals are *synthesized* from fluid state at base-RTT epochs:
//!   virtual ECN marks when a link's time-averaged queue crosses the
//!   shared `kmin` from [`FabricCfg::marking`], RTT samples from path
//!   latency plus summed queue drain times, INT telemetry from the
//!   bottleneck link's queue/tx integrals, loss hints on down links.
//!   The policies see signals, never the engine — this module contains
//!   no per-algorithm branches (docs/SCALE.md §CC-coupled fluid rates).
//!
//! Determinism carries over from the DES core: all ordering runs through
//! the same generic `(time, seq)` [`EventQueue`] (wheel or heap backend),
//! f64 arithmetic happens in fixed link/flow index order, and path
//! choice is the deterministic (tier-salted) ECMP hash — no RNG at all.
//! Replay, wheel-vs-heap, and `--jobs` parity therefore hold bit for bit
//! (pinned in `rust/tests/determinism.rs`).
//!
//! Documented approximations (validated cell-by-cell against the packet
//! engine — docs/SCALE.md §Validation): fluid flows stall on faults
//! instead of losing bytes; sprayed fluid flows ride one hashed path
//! (max-min sharing captures the *average* balance; tail-deciding
//! sprayed last hops are exactly the incast edges the policy forces to
//! packet fidelity); per-flow state is flyweight and `size_of`-guarded
//! so 1k-rank cells stay inside the sweep memory budget.

use std::collections::BTreeSet;

use crate::cc::{CcKind, RateAuthority};
use crate::net::fabric::FabricCfg;
use crate::net::topo::{LinkId, NetFault, Topology, TopologyKind};
use crate::net::NetHints;
use crate::sim::{EventQueue, Metrics, SchedKind, SimTime};
use crate::transport::TransportCfg;
use crate::verbs::NodeId;

/// Index into [`FlowSim`]'s flow table.
pub type FlowId = u32;

/// Which engine a flow (or a whole run) uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FidelityMode {
    /// Every flow at packet fidelity (the in-engine reference).
    Packet,
    /// Every flow fluid (fastest, loosest tails).
    Flow,
    /// Fluid bulk, packet where tails are decided (the default).
    Hybrid,
}

impl FidelityMode {
    pub fn name(&self) -> &'static str {
        match self {
            FidelityMode::Packet => "packet",
            FidelityMode::Flow => "flow",
            FidelityMode::Hybrid => "hybrid",
        }
    }

    pub fn parse(s: &str) -> Option<FidelityMode> {
        match s {
            "packet" => Some(FidelityMode::Packet),
            "flow" | "fluid" => Some(FidelityMode::Flow),
            "hybrid" => Some(FidelityMode::Hybrid),
            _ => None,
        }
    }
}

/// Per-flow fidelity selection rules (tentpole §b). Hybrid keeps a flow
/// fluid only when NOTHING tail-deciding touches it.
#[derive(Clone, Debug)]
pub struct FidelityPolicy {
    pub mode: FidelityMode,
    /// Hybrid: flows shorter than this stay at packet fidelity (short
    /// flows are latency- not bandwidth-bound; the fluid model has no
    /// latency tail for them).
    pub bulk_threshold_bytes: u64,
    /// Hybrid: once this many flows concurrently target one edge link,
    /// further arrivals there run at packet fidelity (incast is decided
    /// by per-packet queueing).
    pub incast_fanin: u32,
    /// Links where tails are decided regardless of flow size: anything a
    /// fault touches is added automatically; scenarios/benches may
    /// designate more (e.g. a probed last hop).
    designated: BTreeSet<LinkId>,
}

impl FidelityPolicy {
    /// Reference policy: everything packet.
    pub fn packet() -> FidelityPolicy {
        FidelityPolicy {
            mode: FidelityMode::Packet,
            bulk_threshold_bytes: 0,
            incast_fanin: u32::MAX,
            designated: BTreeSet::new(),
        }
    }

    /// Everything fluid.
    pub fn flow() -> FidelityPolicy {
        FidelityPolicy {
            mode: FidelityMode::Flow,
            bulk_threshold_bytes: 0,
            incast_fanin: u32::MAX,
            designated: BTreeSet::new(),
        }
    }

    /// Hybrid with the default thresholds: 256 KiB bulk cut-off, fan-in
    /// of 8 (past a ring/tree's structural fan-in, into incast regime).
    pub fn hybrid() -> FidelityPolicy {
        FidelityPolicy {
            mode: FidelityMode::Hybrid,
            bulk_threshold_bytes: 256 * 1024,
            incast_fanin: 8,
            designated: BTreeSet::new(),
        }
    }

    pub fn of(mode: FidelityMode) -> FidelityPolicy {
        match mode {
            FidelityMode::Packet => FidelityPolicy::packet(),
            FidelityMode::Flow => FidelityPolicy::flow(),
            FidelityMode::Hybrid => FidelityPolicy::hybrid(),
        }
    }

    /// Force packet fidelity on every flow whose path touches `link`.
    pub fn designate(&mut self, link: LinkId) {
        self.designated.insert(link);
    }

    pub fn is_designated(&self, link: LinkId) -> bool {
        self.designated.contains(&link)
    }
}

/// Per-link fluid state: capacity for the water-filling solver plus the
/// store-and-forward horizon for packet-fidelity walks. Flyweight —
/// a 1k-rank fat-tree owns ~10k of these.
#[derive(Clone, Copy, Debug)]
pub struct FluidLink {
    /// Capacity, bytes/ns (0 while the link is down).
    pub cap: f64,
    /// Packet-walk horizon: when the link finishes its last serialization.
    pub free_at: SimTime,
    /// Admin state (mirrors `Port::up`).
    pub up: bool,
    /// Routing-convergence mask (mirrors `Port::routed_out`).
    pub routed_out: bool,
}

/// Flyweight per-flow state (PR 4 discipline: compile-time size guard
/// below keeps 10k-rank sweeps honest). Path is inline — the longest
/// Clos-family path (cross-pod fat-tree) is exactly 6 links.
#[derive(Clone, Copy, Debug)]
pub struct Flow {
    pub src: u32,
    pub dst: u32,
    /// Total flow size, bytes.
    pub bytes: u64,
    /// Bytes not yet drained (fluid) / not yet injected (packet).
    pub remaining: f64,
    /// Current max-min allocation, bytes/ns (0 = stalled).
    pub rate: f64,
    /// Link ids, `path[..hops]` valid.
    pub path: [u32; 6],
    pub hops: u8,
    /// Bit 0: fluid; bit 1: spray; bit 2: done.
    flags: u8,
    /// Event generation: completion/step events carry the generation they
    /// were scheduled under and are ignored if the flow was re-solved or
    /// re-pathed since (lazy cancellation — no queue surgery).
    pub gen: u32,
}

const FL_FLUID: u8 = 1;
const FL_SPRAY: u8 = 2;
const FL_DONE: u8 = 4;

// Flyweight guards: a 4096-rank all-to-all step is ~16M flows; at 64 B
// that is 1 GiB — tight but budgetable. Growth fails the build loudly.
const _: () = assert!(std::mem::size_of::<Flow>() <= 64);
const _: () = assert!(std::mem::size_of::<FluidLink>() <= 32);

impl Flow {
    pub fn is_fluid(&self) -> bool {
        self.flags & FL_FLUID != 0
    }
    pub fn is_spray(&self) -> bool {
        self.flags & FL_SPRAY != 0
    }
    pub fn is_done(&self) -> bool {
        self.flags & FL_DONE != 0
    }
}

#[derive(Clone, Copy, Debug)]
enum FsEvent {
    /// A flow enters the fabric (path + fidelity decided here).
    Arrive(FlowId),
    /// Predicted fluid drain end (valid only if `gen` still matches).
    Complete { flow: FlowId, gen: u32 },
    /// Packet-fidelity pacing step: inject one MTU (valid per `gen`).
    Step { flow: FlowId, gen: u32 },
    /// Link-level fault, same vocabulary as the packet engine.
    Fault(NetFault),
    /// CC plane epoch: synthesize signals from fluid link state, tick
    /// every endpoint, refresh rate caps (self-rearming while armed).
    CcEpoch,
}

/// How many consecutive epochs with no acked bytes and no cap movement
/// before the plane stops self-rearming (a wedged run — partitioned
/// fabric, every rate at its floor — must let the event queue drain; a
/// later arrival or fault re-arms it).
const CC_IDLE_EPOCH_LIMIT: u32 = 64;

/// The CC coupling plane: one [`RateAuthority`] — the same seam the
/// packet engine's driver owns — plus per-flow side tables (the
/// [`Flow`] flyweight is at its 64 B budget) and per-link virtual-queue
/// / tx-byte integrals the epoch handler synthesizes signals from.
/// Entirely optional: `cc: None` keeps the solver byte-identical to the
/// uncapped fill.
struct CcPlane {
    ra: RateAuthority,
    m: Metrics,
    /// Per-flow CC rate cap, bytes/ns (`min(rate, cwnd/base_rtt)`).
    cap: Vec<f64>,
    /// Bytes already reported to the flow's CC instance as AckBatches.
    fed: Vec<f64>,
    /// Per-link virtual queue, bytes: integral of (CC-allowed offered
    /// load − drain capacity), clamped to the configured queue cap.
    vq: Vec<f64>,
    /// Per-link transmitted-byte integral (INT telemetry tx counter).
    tx: Vec<f64>,
    /// Shared ECN marking threshold (`FabricCfg::marking().kmin`), bytes.
    kmin: f64,
    /// Virtual-queue clamp (`queue_cap_bytes`).
    vq_cap: f64,
    /// Epoch cadence: one base RTT.
    epoch_ns: u64,
    /// A `CcEpoch` event is in flight.
    armed: bool,
    /// Consecutive epochs without progress (see [`CC_IDLE_EPOCH_LIMIT`]).
    idle_epochs: u32,
}

impl std::fmt::Debug for CcPlane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CcPlane")
            .field("kind", &self.ra.kind())
            .field("endpoints", &self.ra.endpoints())
            .field("epoch_ns", &self.epoch_ns)
            .finish_non_exhaustive()
    }
}

/// The hybrid engine. Owns its own event queue (same deterministic
/// `(time, seq)` core as the cluster DES), a flyweight flow table, and
/// one [`FluidLink`] per fabric link plus one virtual NIC-uplink link
/// per host (the sender-side line-rate limit).
#[derive(Debug)]
pub struct FlowSim {
    pub topo: Topology,
    pub policy: FidelityPolicy,
    pub links: Vec<FluidLink>,
    pub flows: Vec<Flow>,
    events: EventQueue<FsEvent>,
    pub time: SimTime,
    /// Virtual clock of the last fluid advance (remaining-byte bookkeeping).
    last_adv: SimTime,
    /// Lazy re-solve flag: arrivals/departures/faults within one event
    /// batch trigger ONE water-fill, not one each.
    dirty: bool,
    /// Active (arrived, not done) flow ids in arrival order.
    active: Vec<FlowId>,
    /// Concurrent flows targeting each host's edge link (incast policy).
    fanin: Vec<u32>,
    /// XORed into every ECMP label: lets sweep iterations re-roll path
    /// collisions deterministically (the tail-variance knob).
    pub ecmp_salt: u64,
    /// Completions since the last drain: `(flow, finish_time)`.
    completions: std::collections::VecDeque<(FlowId, SimTime)>,
    /// Finish time per flow (`u64::MAX` = not finished).
    finish: Vec<SimTime>,
    // timing constants
    prop_ns: u64,
    switch_ns: u64,
    reroute_ns: u64,
    rto_ns: u64,
    pub mtu_bytes: usize,
    // stats
    pub fluid_started: u64,
    pub packet_started: u64,
    pub completed: u64,
    pub pkts_walked: u64,
    pub pkts_dropped: u64,
    pub resolves: u64,
    /// CC epochs processed (0 while the plane is off).
    pub cc_epochs: u64,
    /// Flow-epochs that saw a synthesized ECN mark.
    pub cc_marks: u64,
    /// CC coupling plane (`None` = uncapped fair-share rates, bit for
    /// bit the pre-coupling solver).
    cc: Option<CcPlane>,
}

impl FlowSim {
    pub fn new(cfg: &FabricCfg, policy: FidelityPolicy, sched: SchedKind) -> FlowSim {
        let topo = cfg.topology();
        let edge_cap = cfg.link_gbps / 8.0; // bytes/ns
        let core_cap = cfg.core_gbps_eff() / 8.0;
        let n = topo.n_links() + topo.nodes; // + virtual NIC uplinks
        let links = (0..n)
            .map(|l| FluidLink {
                cap: if l < topo.n_links() && !topo.is_edge(l) {
                    core_cap
                } else {
                    edge_cap
                },
                free_at: 0,
                up: true,
                routed_out: false,
            })
            .collect();
        FlowSim {
            topo,
            policy,
            links,
            flows: Vec::new(),
            events: EventQueue::with_kind(sched),
            time: 0,
            last_adv: 0,
            dirty: false,
            active: Vec::new(),
            fanin: vec![0; topo.nodes],
            ecmp_salt: 0,
            completions: std::collections::VecDeque::new(),
            finish: Vec::new(),
            prop_ns: cfg.prop_delay_ns,
            switch_ns: cfg.switch_delay_ns,
            reroute_ns: cfg.reroute_ns,
            rto_ns: 3 * cfg.base_rtt_ns().max(1),
            mtu_bytes: 4096,
            fluid_started: 0,
            packet_started: 0,
            completed: 0,
            pkts_walked: 0,
            pkts_dropped: 0,
            resolves: 0,
            cc_epochs: 0,
            cc_marks: 0,
            cc: None,
        }
    }

    /// Couple the fluid plane to a congestion-control policy: every flow
    /// gets a CC instance behind the shared [`RateAuthority`], fed with
    /// signals synthesized from fluid link state at epoch boundaries
    /// (one epoch = one base RTT), and the water-fill caps each flow at
    /// `min(fair_share, cc_rate)`. Applies to flows of BOTH fidelities
    /// (packet-fidelity pacing chains run at the capped rate too). Call
    /// before running the simulation.
    pub fn enable_cc(&mut self, kind: CcKind, cfg: &FabricCfg) {
        let tc = TransportCfg::from_fabric(cfg).with_cc(kind);
        let mark = cfg.marking();
        let n = self.links.len();
        self.cc = Some(CcPlane {
            ra: RateAuthority::new(&tc),
            m: Metrics::new(),
            cap: vec![f64::INFINITY; self.flows.len()],
            fed: vec![0.0; self.flows.len()],
            vq: vec![0.0; n],
            tx: vec![0.0; n],
            kmin: mark.kmin as f64,
            vq_cap: cfg.queue_cap_bytes as f64,
            epoch_ns: cfg.base_rtt_ns().max(1),
            armed: false,
            idle_epochs: 0,
        });
    }

    /// The coupled CC policy, if the plane is on.
    pub fn cc_kind(&self) -> Option<CcKind> {
        self.cc.as_ref().map(|c| c.ra.kind())
    }

    /// A counter from the CC plane's metrics (0 while the plane is off).
    pub fn cc_counter(&self, name: &str) -> u64 {
        self.cc.as_ref().map_or(0, |c| c.m.counter(name))
    }

    /// The virtual sender-side NIC uplink for `host` (line-rate cap).
    pub fn nic_link(&self, host: NodeId) -> LinkId {
        self.topo.n_links() + host
    }

    /// Register a flow of `bytes` from `src` to `dst`, arriving at `at`
    /// (clamped to now). Path and fidelity are decided at arrival time.
    pub fn inject(&mut self, at: SimTime, src: NodeId, dst: NodeId, bytes: u64) -> FlowId {
        self.inject_opt(at, src, dst, bytes, false)
    }

    pub fn inject_opt(
        &mut self,
        at: SimTime,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
        spray: bool,
    ) -> FlowId {
        assert!(src != dst, "self-flow");
        assert!(src < self.topo.nodes && dst < self.topo.nodes, "host out of range");
        let id = self.flows.len() as FlowId;
        self.flows.push(Flow {
            src: src as u32,
            dst: dst as u32,
            bytes,
            remaining: bytes as f64,
            rate: 0.0,
            path: [0; 6],
            hops: 0,
            flags: if spray { FL_SPRAY } else { 0 },
            gen: 0,
        });
        self.finish.push(SimTime::MAX);
        if let Some(cc) = &mut self.cc {
            cc.cap.push(f64::INFINITY);
            cc.fed.push(0.0);
        }
        self.events.push(at.max(self.time), FsEvent::Arrive(id));
        id
    }

    /// Schedule a link fault (same `NetFault` vocabulary as the packet
    /// engine). A `LinkDown` auto-schedules its `RerouteOut` after the
    /// configured convergence delay and designates the link so new flows
    /// crossing it run at packet fidelity.
    pub fn fault(&mut self, at: SimTime, fault: NetFault) {
        if let NetFault::LinkDown(l) | NetFault::Degrade(l, _) = fault {
            self.policy.designate(l);
        }
        self.events.push(at.max(self.time), FsEvent::Fault(fault));
    }

    /// The links flow `f`'s packets traverse (in order).
    pub fn flow_path(&self, f: FlowId) -> &[u32] {
        let fl = &self.flows[f as usize];
        &fl.path[..fl.hops as usize]
    }

    pub fn finish_time(&self, f: FlowId) -> Option<SimTime> {
        let t = self.finish[f as usize];
        (t != SimTime::MAX).then_some(t)
    }

    /// Completions recorded since the last call, in completion order.
    pub fn drain_completions(&mut self) -> Vec<(FlowId, SimTime)> {
        self.completions.drain(..).collect()
    }

    /// Advance the simulation until the next flow completes and return it
    /// (`None` once the event queue drains — any remaining flows are
    /// stalled, e.g. on a partitioned fabric). This is the hook the scale
    /// runner's step-dependency engine drives collectives with.
    pub fn run_next_completion(&mut self) -> Option<(FlowId, SimTime)> {
        loop {
            if let Some(c) = self.completions.pop_front() {
                return Some(c);
            }
            let t = self.events.peek_time()?;
            self.time = t;
            while self.events.peek_time() == Some(t) {
                let (_, ev) = self.events.pop().unwrap();
                self.handle(t, ev);
            }
            if self.dirty {
                self.resolve(t);
            }
        }
    }

    /// Run until the event queue drains or the clock passes `t_end`.
    pub fn run_until(&mut self, t_end: SimTime) {
        while let Some(t) = self.events.peek_time() {
            if t > t_end {
                break;
            }
            self.time = t;
            // drain the whole same-timestamp batch, then re-solve once
            while self.events.peek_time() == Some(t) {
                let (_, ev) = self.events.pop().unwrap();
                self.handle(t, ev);
            }
            if self.dirty {
                self.resolve(t);
            }
        }
    }

    /// Run until no events remain (stalled flows on a partitioned fabric
    /// simply never finish — check [`FlowSim::finish_time`]).
    pub fn run_to_completion(&mut self) {
        self.run_until(SimTime::MAX);
    }

    // ---- event handling -----------------------------------------------------

    fn handle(&mut self, now: SimTime, ev: FsEvent) {
        match ev {
            FsEvent::Arrive(f) => self.on_arrive(now, f),
            FsEvent::Complete { flow, gen } => self.on_complete(now, flow, gen),
            FsEvent::Step { flow, gen } => self.on_step(now, flow, gen),
            FsEvent::Fault(nf) => self.on_fault(now, nf),
            FsEvent::CcEpoch => self.on_cc_epoch(now),
        }
    }

    fn on_arrive(&mut self, now: SimTime, f: FlowId) {
        let (src, dst) = {
            let fl = &self.flows[f as usize];
            (fl.src as usize, fl.dst as usize)
        };
        let (path, hops) = self.build_path(src, dst, f as u64, 0);
        let fluid = self.choose_fluid(self.flows[f as usize].bytes, &path[..hops as usize], dst);
        {
            let fl = &mut self.flows[f as usize];
            fl.path = path;
            fl.hops = hops;
            if fluid {
                fl.flags |= FL_FLUID;
            }
        }
        self.fanin[dst] += 1;
        self.active.push(f);
        if fluid {
            self.fluid_started += 1;
        } else {
            self.packet_started += 1;
        }
        if let Some(cc) = &mut self.cc {
            // flow ids double as endpoint ids on the shared seam (both
            // are u32); demand is announced up front so credit-based
            // schemes can start granting from the first epoch
            cc.ra.register(f);
            cc.ra.announce(f, self.flows[f as usize].bytes as usize);
            cc.cap[f as usize] = cc.ra.rate_cap(f);
        }
        self.arm_epoch(now);
        // rates (and the packet pacing chain, via the 0→rate transition
        // in resolve) are assigned by the batch-end water-fill
        self.dirty = true;
    }

    /// (Re-)arm the CC epoch clock and reset the idle counter — called
    /// on arrivals and faults, the two externally-driven ways a wedged
    /// plane can start moving again. No-op while the plane is off.
    fn arm_epoch(&mut self, now: SimTime) {
        let Some(cc) = &mut self.cc else { return };
        cc.idle_epochs = 0;
        if cc.armed {
            return;
        }
        cc.armed = true;
        let e = cc.epoch_ns;
        self.events.push(now + e, FsEvent::CcEpoch);
    }

    /// One CC epoch: synthesize per-flow congestion signals from fluid
    /// link state, feed them through the shared [`RateAuthority`], and
    /// refresh every flow's rate cap for the batch-end re-solve. Flows
    /// are visited in arrival order and each path is read in link
    /// order, so the pass is fully deterministic.
    fn on_cc_epoch(&mut self, now: SimTime) {
        self.advance_to(now);
        let mtu = self.mtu_bytes;
        let (prop, sw) = (self.prop_ns, self.switch_ns);
        let Some(cc) = &mut self.cc else { return };
        let mut any_active = false;
        let mut progress = false;
        let mut marks = 0u64;
        for &f in &self.active {
            let fl = &self.flows[f as usize];
            if fl.is_done() {
                continue;
            }
            any_active = true;
            let hops = fl.hops as usize;
            let path = &fl.path[..hops];
            // one walk over the path: the bottleneck is the fabric link
            // with the longest virtual-queue drain time (lowest id on
            // ties via strict >), the RTT sample picks up the summed
            // drain times, and marks fire deterministically at kmin —
            // the time-averaged vq subsumes the packet path's RED
            // lottery (same thresholds via FabricCfg::marking)
            let mut bl = path[hops - 1] as usize;
            let mut worst = -1.0f64;
            let mut qdelay = 0.0f64;
            let mut down = false;
            let mut marked = false;
            for (i, &l) in path.iter().enumerate() {
                let l = l as usize;
                let link = &self.links[l];
                down |= !link.up;
                let drain = if link.cap > 0.0 { cc.vq[l] / link.cap } else { 0.0 };
                qdelay += drain;
                if i == 0 {
                    continue; // virtual NIC uplink: hosts don't mark or stamp INT
                }
                if cc.vq[l] >= cc.kmin {
                    marked = true;
                }
                if drain > worst {
                    worst = drain;
                    bl = l;
                }
            }
            if marked {
                marks += 1;
            }
            let drained = fl.bytes as f64 - fl.remaining;
            let acked = (drained - cc.fed[f as usize]).max(0.0);
            cc.fed[f as usize] = drained;
            if down {
                // same wire fact the packet engine reports on a
                // blackholed fragment: a NACK-grade loss hint
                cc.ra.on_loss(f, now, false);
            }
            if acked >= 1.0 || marked {
                let link = &self.links[bl];
                let hints = NetHints {
                    qdepth: cc.vq[bl].min(u32::MAX as f64) as u32,
                    ecn: marked,
                    tx_bytes: cc.tx[bl] as u64,
                    link_mbps: (link.cap * 8000.0) as u32,
                    // fabric hops only — the driver re-adds the host uplink
                    hops: (hops - 1) as u8,
                };
                let base_ow = hops as u64 * prop + (hops as u64 - 1) * sw;
                let rtt = 2 * base_ow + qdelay as u64;
                cc.ra.on_ack(&mut cc.m, f, now, Some(rtt), acked as usize, &hints);
                cc.ra.consume(f, acked as usize, mtu);
                progress = true;
            }
            cc.ra.epoch_tick(&mut cc.m, f, now, mtu);
            let new_cap = cc.ra.rate_cap(f);
            if (new_cap - cc.cap[f as usize]).abs() > 1e-12 {
                progress = true;
            }
            cc.cap[f as usize] = new_cap;
        }
        if progress {
            cc.idle_epochs = 0;
        } else {
            cc.idle_epochs = cc.idle_epochs.saturating_add(1);
        }
        // keep ticking while flows are in flight and the plane is still
        // moving; a fully wedged run stops arming so the queue can
        // drain — arrivals and faults re-arm via arm_epoch
        let rearm = any_active && cc.idle_epochs < CC_IDLE_EPOCH_LIMIT;
        cc.armed = rearm;
        let e = cc.epoch_ns;
        self.cc_marks += marks;
        self.cc_epochs += 1;
        self.dirty = true;
        if rearm {
            self.events.push(now + e, FsEvent::CcEpoch);
        }
    }

    fn on_complete(&mut self, now: SimTime, f: FlowId, gen: u32) {
        let fl = &self.flows[f as usize];
        if fl.gen != gen || fl.is_done() || !fl.is_fluid() {
            return; // stale prediction, superseded by a re-solve
        }
        // the prediction was ceil(remaining / rate) ahead — the advance
        // at this batch's start drained remaining to (numerically) zero
        self.finish_flow(f, now + self.path_latency(self.flows[f as usize].hops));
    }

    fn on_step(&mut self, now: SimTime, f: FlowId, gen: u32) {
        let fl = &self.flows[f as usize];
        if fl.gen != gen || fl.is_done() || fl.is_fluid() {
            return;
        }
        if fl.rate <= 0.0 {
            return; // stalled: the chain dies, a re-solve revives it
        }
        // re-path lazily if convergence masked a link under us
        if self
            .flow_path(f)
            .iter()
            .any(|&l| self.links[l as usize].routed_out)
        {
            let (src, dst) = (fl.src as usize, fl.dst as usize);
            let (path, hops) = self.build_path(src, dst, f as u64, 0);
            let fl = &mut self.flows[f as usize];
            fl.path = path;
            fl.hops = hops;
        }
        let fl = &self.flows[f as usize];
        let size = (fl.remaining.min(self.mtu_bytes as f64)).max(1.0) as u64;
        // walk the packet through the path's store-and-forward horizons;
        // sprayed flows rotate their up-level choice per packet
        let pkt_idx = ((fl.bytes as f64 - fl.remaining) / self.mtu_bytes as f64) as u64;
        let walk_path = if fl.is_spray() {
            let (src, dst) = (fl.src as usize, fl.dst as usize);
            let (p, h) = self.build_path(src, dst, f as u64, pkt_idx);
            p[..h as usize].to_vec()
        } else {
            self.flow_path(f).to_vec()
        };
        let mut arrive = now;
        for (i, &l) in walk_path.iter().enumerate() {
            let link = &mut self.links[l as usize];
            if !link.up {
                // blackhole: lose the packet, retransmit after an RTO
                self.pkts_dropped += 1;
                if let Some(cc) = &mut self.cc {
                    // the drop is a NACK-grade loss hint on the seam,
                    // exactly what the packet engine would report
                    cc.ra.on_loss(f, now, false);
                }
                let gen = self.flows[f as usize].gen;
                self.events.push(now + self.rto_ns, FsEvent::Step { flow: f, gen });
                return;
            }
            let ser = (size as f64 / link.cap).ceil() as u64;
            let depart = arrive.max(link.free_at) + ser;
            link.free_at = depart;
            arrive = depart + self.prop_ns;
            if i + 1 < walk_path.len() {
                arrive += self.switch_ns;
            }
        }
        self.pkts_walked += 1;
        let fl = &mut self.flows[f as usize];
        fl.remaining -= size as f64;
        if fl.remaining <= 0.5 {
            self.finish_flow(f, arrive);
            return;
        }
        // pace the next injection at the solved fair rate
        let gap = (size as f64 / fl.rate).ceil() as u64;
        let gen = fl.gen;
        self.events.push(now + gap.max(1), FsEvent::Step { flow: f, gen });
    }

    fn on_fault(&mut self, now: SimTime, nf: NetFault) {
        match nf {
            NetFault::LinkDown(l) => {
                let link = &mut self.links[l];
                link.up = false;
                self.events
                    .push(now + self.reroute_ns, FsEvent::Fault(NetFault::RerouteOut(l)));
            }
            NetFault::RerouteOut(l) => {
                if !self.links[l].up {
                    self.links[l].routed_out = true;
                    // fluid flows crossing the dead link re-path now
                    // (packet flows re-path lazily at their next step)
                    for i in 0..self.active.len() {
                        let f = self.active[i];
                        let fl = &self.flows[f as usize];
                        if fl.is_done() || !fl.is_fluid() {
                            continue;
                        }
                        if self.flow_path(f).iter().any(|&pl| pl as usize == l) {
                            let (src, dst) = (fl.src as usize, fl.dst as usize);
                            let (path, hops) = self.build_path(src, dst, f as u64, 0);
                            let fl = &mut self.flows[f as usize];
                            fl.path = path;
                            fl.hops = hops;
                        }
                    }
                }
            }
            NetFault::LinkUp(l) => {
                self.links[l].up = true;
                self.links[l].routed_out = false;
            }
            NetFault::Degrade(_, _) => {
                // fluid capacities model degradation poorly (serialization
                // stretch is per packet); degraded links are designated at
                // schedule time, so affected flows run at packet fidelity
                // where the walk's horizons price the slowdown naturally
            }
        }
        // topology changes can unwedge an idle CC plane (e.g. a LinkUp
        // reviving a partitioned path) — restart the epoch clock
        self.arm_epoch(now);
        self.dirty = true;
    }

    fn finish_flow(&mut self, f: FlowId, at: SimTime) {
        let fl = &mut self.flows[f as usize];
        fl.flags |= FL_DONE;
        fl.remaining = 0.0;
        fl.rate = 0.0;
        fl.gen = fl.gen.wrapping_add(1);
        let dst = fl.dst as usize;
        self.fanin[dst] -= 1;
        self.finish[f as usize] = at;
        self.completions.push((f, at));
        self.completed += 1;
        if let Some(cc) = &mut self.cc {
            // release the endpoint's CC state promptly — the memory
            // model charges live endpoints only
            cc.ra.unregister(f);
        }
        self.dirty = true;
    }

    // ---- fluid solver -------------------------------------------------------

    /// Drain `remaining` for every active fluid flow up to `now` at the
    /// current allocation.
    fn advance_to(&mut self, now: SimTime) {
        let dt = now.saturating_sub(self.last_adv);
        self.last_adv = now;
        if dt == 0 {
            return;
        }
        for &f in &self.active {
            let fl = &mut self.flows[f as usize];
            if fl.is_done() || !fl.is_fluid() || fl.rate <= 0.0 {
                continue;
            }
            fl.remaining = (fl.remaining - fl.rate * dt as f64).max(0.0);
        }
        // integrate the CC plane's virtual queues over the same window:
        // a link's vq grows while the CC-allowed offered load exceeds
        // its drain capacity and drains otherwise (idle links drain
        // too) — time-averaged occupancy, the fluid stand-in for the
        // packet path's RED smoothing
        if let Some(cc) = &mut self.cc {
            let dtf = dt as f64;
            let n = self.links.len();
            let mut offered = vec![0.0f64; n];
            let mut actual = vec![0.0f64; n];
            for &f in &self.active {
                let fl = &self.flows[f as usize];
                if fl.is_done() {
                    continue;
                }
                let capf = cc.cap[f as usize];
                for &l in &fl.path[..fl.hops as usize] {
                    offered[l as usize] += capf;
                    actual[l as usize] += fl.rate;
                }
            }
            for l in 0..n {
                if offered[l] == 0.0 && actual[l] == 0.0 && cc.vq[l] == 0.0 {
                    continue;
                }
                let link = &self.links[l];
                let drain = if link.up { link.cap } else { 0.0 };
                cc.vq[l] = (cc.vq[l] + (offered[l] - drain) * dtf).clamp(0.0, cc.vq_cap);
                if actual[l] > 0.0 {
                    cc.tx[l] += actual[l] * dtf;
                }
            }
        }
    }

    /// Max-min water-filling over all active flows (both fidelities —
    /// packet flows consume their pacing share too), then reschedule
    /// completion predictions (fluid) and revive stalled pacing chains
    /// (packet). Deterministic: links scanned in ascending id order,
    /// flows in arrival order.
    fn resolve(&mut self, now: SimTime) {
        self.advance_to(now);
        self.dirty = false;
        self.resolves += 1;
        self.active.retain(|&f| !self.flows[f as usize].is_done());

        // CC cap snapshot, one entry per active flow (empty while the
        // plane is off — the fill below is then byte-identical to the
        // uncapped solver)
        let flow_cap: Vec<f64> = match &self.cc {
            Some(cc) => self.active.iter().map(|&f| cc.cap[f as usize]).collect(),
            None => Vec::new(),
        };

        let n_links = self.links.len();
        let mut cap = vec![0.0f64; n_links];
        let mut load = vec![0u32; n_links];
        // only links some active flow crosses can be bottlenecks — the
        // water-fill scans this set, not all O(10k) fabric links, so a
        // 1k-rank cell's re-solve cost tracks the ACTIVE flow count
        let mut touched: Vec<usize> = Vec::new();
        for &f in &self.active {
            for &l in self.flow_path(f) {
                if load[l as usize] == 0 {
                    cap[l as usize] = if self.links[l as usize].up {
                        self.links[l as usize].cap
                    } else {
                        0.0
                    };
                    touched.push(l as usize);
                }
                load[l as usize] += 1;
            }
        }
        touched.sort_unstable(); // "lowest link id on ties" stays exact
        let mut frozen: Vec<bool> = vec![false; self.active.len()];
        let prev_rates: Vec<f64> = self
            .active
            .iter()
            .map(|&f| self.flows[f as usize].rate)
            .collect();
        loop {
            // most-contended link: smallest fair share, lowest id on ties
            let mut best: Option<(f64, usize)> = None;
            for &l in &touched {
                let n = load[l];
                if n == 0 {
                    continue;
                }
                let share = cap[l] / n as f64;
                if best.is_none() || share < best.unwrap().0 {
                    best = Some((share, l));
                }
            }
            let Some((share, bottleneck)) = best else { break };
            // rate-authority pass: a flow whose CC cap sits at or below
            // the current water level can never fill a fair share —
            // freeze it at min(fair_share, cc_cap) = cc_cap and release
            // the slack. Water levels are non-decreasing across rounds,
            // so capping early never starves a later bottleneck.
            if !flow_cap.is_empty() {
                let mut capped_any = false;
                for (i, &f) in self.active.iter().enumerate() {
                    if frozen[i] || flow_cap[i] > share {
                        continue;
                    }
                    frozen[i] = true;
                    capped_any = true;
                    let r = flow_cap[i].max(0.0);
                    self.flows[f as usize].rate = r;
                    for &l in self.flow_path(f) {
                        cap[l as usize] = (cap[l as usize] - r).max(0.0);
                        load[l as usize] -= 1;
                    }
                }
                if capped_any {
                    continue; // shares may have grown — re-find the bottleneck
                }
            }
            // freeze every unfrozen flow crossing it at that share
            for (i, &f) in self.active.iter().enumerate() {
                if frozen[i] {
                    continue;
                }
                if !self.flow_path(f).iter().any(|&l| l as usize == bottleneck) {
                    continue;
                }
                frozen[i] = true;
                self.flows[f as usize].rate = share;
                for &l in self.flow_path(f) {
                    cap[l as usize] = (cap[l as usize] - share).max(0.0);
                    load[l as usize] -= 1;
                }
            }
            debug_assert_eq!(load[bottleneck], 0, "bottleneck must clear");
        }
        // reschedule predictions under the new allocation
        for (i, &f) in self.active.iter().enumerate() {
            let fl = &mut self.flows[f as usize];
            if fl.is_fluid() {
                fl.gen = fl.gen.wrapping_add(1);
                if fl.rate > 1e-12 {
                    let drain = (fl.remaining / fl.rate).ceil() as u64;
                    let gen = fl.gen;
                    self.events.push(now + drain, FsEvent::Complete { flow: f, gen });
                }
            } else if prev_rates[i] <= 0.0 && fl.rate > 0.0 {
                // packet chain was never started (or stalled): revive it
                fl.gen = fl.gen.wrapping_add(1);
                let gen = fl.gen;
                self.events.push(now, FsEvent::Step { flow: f, gen });
            }
        }
    }

    // ---- paths & policy -----------------------------------------------------

    /// Base one-way latency of an `hops`-link path (props + switch
    /// traversals; the store-and-forward serialization is what the fluid
    /// drain / packet walk accounts separately).
    fn path_latency(&self, hops: u8) -> u64 {
        hops as u64 * self.prop_ns + (hops as u64 - 1) * self.switch_ns
    }

    /// Deterministic path for `src → dst` with ECMP label `label` (the
    /// flow id) — same hash family as the packet engine, masked by
    /// routing convergence exactly like `Fabric::pick_spine`. `salt`
    /// rotates the up-level choices for sprayed packet walks.
    fn build_path(&self, src: NodeId, dst: NodeId, label: u64, salt: u64) -> ([u32; 6], u8) {
        let label = label ^ self.ecmp_salt;
        let t = &self.topo;
        let mut path = [0u32; 6];
        let mut h = 0usize;
        path[h] = self.nic_link(src) as u32;
        h += 1;
        match t.kind {
            TopologyKind::SingleSwitch => {
                path[h] = t.host_link(dst) as u32;
                h += 1;
            }
            TopologyKind::LeafSpine { spines, .. } => {
                let (ls, ld) = (t.host_leaf(src), t.host_leaf(dst));
                if ls != ld {
                    let hash = Topology::ecmp_hash(src, dst, label).wrapping_add(salt);
                    let s = self.pick_masked(t.up_link(ls, 0), spines, hash);
                    path[h] = t.up_link(ls, s) as u32;
                    h += 1;
                    path[h] = t.down_link(s, ld) as u32;
                    h += 1;
                }
                path[h] = t.host_link(dst) as u32;
                h += 1;
            }
            TopologyKind::FatTree {
                leaves_per_pod,
                spines_per_pod,
                core,
                ..
            } => {
                let (ls, ld) = (t.host_leaf(src), t.host_leaf(dst));
                if ls != ld {
                    let hash1 =
                        Topology::ecmp_hash_tier(src, dst, label, 1).wrapping_add(salt);
                    let s = self.pick_masked(t.ft_up1(ls, 0), spines_per_pod, hash1);
                    let ps = t.leaf_pod(ls) * spines_per_pod + s;
                    path[h] = t.ft_up1(ls, s) as u32;
                    h += 1;
                    if t.leaf_pod(ls) != t.leaf_pod(ld) {
                        let hash2 =
                            Topology::ecmp_hash_tier(src, dst, label, 2).wrapping_add(salt);
                        let c = self.pick_masked(t.ft_up2(ps, 0), core, hash2);
                        path[h] = t.ft_up2(ps, c) as u32;
                        h += 1;
                        let hash3 =
                            Topology::ecmp_hash_tier(src, dst, label, 3).wrapping_add(salt);
                        let dpod = t.leaf_pod(ld);
                        let s2 = self
                            .pick_masked(t.ft_down2(c, dpod * spines_per_pod), spines_per_pod, hash3);
                        let ps2 = dpod * spines_per_pod + s2;
                        path[h] = t.ft_down2(c, ps2) as u32;
                        h += 1;
                        path[h] = t.ft_down1(ps2, ld % leaves_per_pod) as u32;
                        h += 1;
                    } else {
                        path[h] = t.ft_down1(ps, ld % leaves_per_pod) as u32;
                        h += 1;
                    }
                }
                path[h] = t.host_link(dst) as u32;
                h += 1;
            }
        }
        (path, h as u8)
    }

    /// Hash-pick among `n` consecutive candidate links from `first`,
    /// skipping convergence-masked ones (full set when all are masked —
    /// the partitioned-fabric contract the packet engine has).
    fn pick_masked(&self, first: LinkId, n: usize, hash: u64) -> usize {
        let ok = |i: usize| !self.links[first + i].routed_out;
        let n_ok = (0..n).filter(|&i| ok(i)).count();
        if n_ok == 0 {
            return (hash % n as u64) as usize;
        }
        let mut k = (hash % n_ok as u64) as usize;
        for i in 0..n {
            if ok(i) {
                if k == 0 {
                    return i;
                }
                k -= 1;
            }
        }
        unreachable!("k < n_ok")
    }

    fn choose_fluid(&self, bytes: u64, path: &[u32], dst: NodeId) -> bool {
        match self.policy.mode {
            FidelityMode::Packet => false,
            FidelityMode::Flow => true,
            FidelityMode::Hybrid => {
                bytes >= self.policy.bulk_threshold_bytes
                    && self.fanin[dst] < self.policy.incast_fanin
                    && !path.iter().any(|&l| self.policy.is_designated(l as usize))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 10 G single switch, 100 ns prop, 50 ns switch — cap 1.25 B/ns.
    fn ss_cfg(nodes: usize) -> FabricCfg {
        let mut cfg = FabricCfg::cloudlab(nodes);
        cfg = cfg.with_link_gbps(10.0);
        cfg.prop_delay_ns = 100;
        cfg.switch_delay_ns = 50;
        cfg
    }

    fn ls_cfg() -> FabricCfg {
        let mut cfg = ss_cfg(4);
        cfg = cfg.with_leaf_spine(2, 2);
        cfg.reroute_ns = 10_000;
        cfg
    }

    fn ft_cfg() -> FabricCfg {
        ss_cfg(16).with_fat_tree(2, 2, 2, 2)
    }

    #[test]
    fn single_fluid_flow_finishes_at_line_rate() {
        let mut fs = FlowSim::new(&ss_cfg(2), FidelityPolicy::flow(), SchedKind::Wheel);
        let f = fs.inject(0, 0, 1, 1_000_000);
        fs.run_to_completion();
        // drain = 1 MB / 1.25 B/ns = 800 000 ns; latency = 2·100 + 1·50
        assert_eq!(fs.finish_time(f), Some(800_000 + 250));
        assert_eq!(fs.completed, 1);
        assert_eq!(fs.fluid_started, 1);
    }

    #[test]
    fn two_flows_share_an_edge_max_min() {
        let mut fs = FlowSim::new(&ss_cfg(3), FidelityPolicy::flow(), SchedKind::Wheel);
        let a = fs.inject(0, 0, 2, 1_000_000);
        let b = fs.inject(0, 1, 2, 1_000_000);
        fs.run_to_completion();
        // both halve the shared edge: 1 MB / 0.625 B/ns = 1.6 ms + latency
        assert_eq!(fs.finish_time(a), Some(1_600_000 + 250));
        assert_eq!(fs.finish_time(b), Some(1_600_000 + 250));
    }

    #[test]
    fn water_fill_is_max_min_not_equal_split() {
        // A: 0→2, B: 1→2 (share edge 2), C: 1→0 (shares nic 1 with B).
        // Max-min: A = B = 0.625 (edge 2); C = nic1 leftover = 0.625.
        // The interesting case: after B frozen at 0.625, C may use the
        // REST of nic 1 — an equal split would starve it at 1.25/2 with
        // no recovery. Here all three end at 0.625, but via two
        // different bottlenecks — then A=B end first only if sizes say so.
        let mut fs = FlowSim::new(&ss_cfg(3), FidelityPolicy::flow(), SchedKind::Wheel);
        let a = fs.inject(0, 0, 2, 500_000);
        let b = fs.inject(0, 1, 2, 500_000);
        let c = fs.inject(0, 1, 0, 250_000);
        fs.run_to_completion();
        // a,b: 500 kB at 0.625 = 800 000 ns; c: 250 kB at 0.625 = 400 000,
        // then b re-solves to nic-limited... sizes chosen so c finishes
        // first and b speeds up: after c departs (at 400 000), b's nic
        // constraint relaxes but edge 2 still pins a and b at 0.625.
        assert_eq!(fs.finish_time(c), Some(400_000 + 250));
        assert_eq!(fs.finish_time(a), Some(800_000 + 250));
        assert_eq!(fs.finish_time(b), Some(800_000 + 250));
    }

    #[test]
    fn packet_mode_tracks_fluid_within_store_and_forward_overhead() {
        let bytes = 40 * 4096u64; // 40 MTUs
        let mut fluid = FlowSim::new(&ss_cfg(2), FidelityPolicy::flow(), SchedKind::Wheel);
        let ff = fluid.inject(0, 0, 1, bytes);
        fluid.run_to_completion();
        let mut pkt = FlowSim::new(&ss_cfg(2), FidelityPolicy::packet(), SchedKind::Wheel);
        let pf = pkt.inject(0, 0, 1, bytes);
        pkt.run_to_completion();
        let (tf, tp) = (fluid.finish_time(ff).unwrap(), pkt.finish_time(pf).unwrap());
        assert!(pkt.pkts_walked >= 40);
        // store-and-forward re-serializes each MTU once per hop, so the
        // packet walk runs one extra serialization long plus per-packet
        // ceil rounding — never faster, and within the documented bound
        assert!(tp >= tf, "packet {tp} must not beat fluid {tf}");
        assert!(
            (tp - tf) as f64 <= 0.15 * tf as f64,
            "packet {tp} vs fluid {tf} exceeds 15% tolerance"
        );
    }

    #[test]
    fn hybrid_forces_packet_on_incast_and_short_flows() {
        let mut policy = FidelityPolicy::hybrid();
        policy.incast_fanin = 4;
        policy.bulk_threshold_bytes = 64 * 1024;
        let mut fs = FlowSim::new(&ss_cfg(10), policy, SchedKind::Wheel);
        // a short flow: packet fidelity by size
        fs.inject(0, 8, 9, 1_000);
        // 8-way incast: the first 3 arrivals are fluid (fan-in 0,1,2 < 4),
        // the rest are packet
        for s in 0..8 {
            fs.inject(0, s, 9, 256 * 1024);
        }
        fs.run_to_completion();
        assert_eq!(fs.fluid_started, 3);
        assert_eq!(fs.packet_started, 6);
        assert_eq!(fs.completed, 9);
    }

    #[test]
    fn designated_links_force_packet_fidelity() {
        let mut policy = FidelityPolicy::hybrid();
        policy.designate(1); // host 1's edge link
        let mut fs = FlowSim::new(&ss_cfg(3), policy, SchedKind::Wheel);
        let a = fs.inject(0, 0, 1, 1 << 20); // crosses designated link
        let b = fs.inject(0, 0, 2, 1 << 20); // does not
        fs.run_to_completion();
        assert!(!fs.flows[a as usize].is_fluid());
        assert!(fs.flows[b as usize].is_fluid());
        assert_eq!((fs.fluid_started, fs.packet_started), (1, 1));
    }

    #[test]
    fn fat_tree_paths_have_the_right_shape() {
        let mut fs = FlowSim::new(&ft_cfg(), FidelityPolicy::flow(), SchedKind::Wheel);
        let same_leaf = fs.inject(0, 0, 1, 4096);
        let same_pod = fs.inject(0, 0, 5, 4096);
        let cross_pod = fs.inject(0, 0, 9, 4096);
        fs.run_to_completion();
        assert_eq!(fs.flow_path(same_leaf).len(), 2); // nic + edge
        assert_eq!(fs.flow_path(same_pod).len(), 4); // + up1 + down1
        assert_eq!(fs.flow_path(cross_pod).len(), 6); // + up2 + down2
        // every flow finished and cross-pod pays the longest latency
        let t1 = fs.finish_time(same_leaf).unwrap();
        let t3 = fs.finish_time(cross_pod).unwrap();
        assert!(t3 > t1);
    }

    #[test]
    fn link_down_stalls_fluid_flow_until_reroute() {
        let cfg = ls_cfg();
        // healthy run for the baseline
        let mut h = FlowSim::new(&cfg, FidelityPolicy::flow(), SchedKind::Wheel);
        let hf = h.inject(0, 0, 2, 1 << 20);
        h.run_to_completion();
        let healthy = h.finish_time(hf).unwrap();
        let up_taken = h.flow_path(hf)[1]; // the chosen leaf→spine link

        let mut fs = FlowSim::new(&cfg, FidelityPolicy::flow(), SchedKind::Wheel);
        let f = fs.inject(0, 0, 2, 1 << 20);
        fs.fault(10, NetFault::LinkDown(up_taken as usize));
        fs.run_to_completion();
        let faulted = fs.finish_time(f).expect("must reroute and finish");
        // stalled from t=10 until convergence (reroute_ns), then full rate
        // on the surviving spine
        assert!(faulted > healthy, "fault must cost time: {faulted} vs {healthy}");
        assert!(faulted >= cfg.reroute_ns, "cannot finish before convergence");
        assert!(!fs.flow_path(f).contains(&up_taken), "must have re-pathed");
    }

    #[test]
    fn wheel_and_heap_agree_bit_for_bit() {
        let run = |sched: SchedKind| {
            let mut fs = FlowSim::new(&ft_cfg(), FidelityPolicy::hybrid(), sched);
            // sizes straddle the bulk threshold: i < 4 packet, i >= 4 fluid
            for i in 0..12usize {
                fs.inject((i as u64) * 1_000, i, (i + 5) % 16, 200 * 1024 + i as u64 * 16 * 1024);
            }
            fs.fault(50_000, NetFault::LinkDown(16)); // first up1 link
            fs.run_to_completion();
            (fs.drain_completions(), fs.resolves, fs.pkts_walked)
        };
        assert_eq!(run(SchedKind::Wheel), run(SchedKind::Heap));
    }

    #[test]
    fn replay_is_identical() {
        let run = || {
            let mut fs = FlowSim::new(&ft_cfg(), FidelityPolicy::hybrid(), SchedKind::Wheel);
            for i in 0..10usize {
                fs.inject(0, i, 15 - i, 1 << 20);
            }
            fs.run_to_completion();
            fs.drain_completions()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn fidelity_mode_names_and_parse_round_trip() {
        for m in [FidelityMode::Packet, FidelityMode::Flow, FidelityMode::Hybrid] {
            assert_eq!(FidelityMode::parse(m.name()), Some(m));
        }
        assert_eq!(FidelityMode::parse("fluid"), Some(FidelityMode::Flow));
        assert_eq!(FidelityMode::parse("nope"), None);
    }

    // ---- CC coupling (tentpole) --------------------------------------------

    #[test]
    fn cc_none_cap_is_line_rate_and_preserves_fair_share_times() {
        // CcKind::None's cap collapses to the line rate, so
        // min(fair_share, cap) = fair_share: finish times must match
        // the uncapped solver exactly (all arithmetic here is dyadic —
        // 1.25 B/ns caps, 500 ns epochs — so epoch-granular advances
        // drain identically to one big advance)
        let base = {
            let mut fs = FlowSim::new(&ss_cfg(3), FidelityPolicy::flow(), SchedKind::Wheel);
            let a = fs.inject(0, 0, 2, 1_000_000);
            let b = fs.inject(0, 1, 2, 1_000_000);
            fs.run_to_completion();
            (fs.finish_time(a), fs.finish_time(b))
        };
        let cfg = ss_cfg(3);
        let mut fs = FlowSim::new(&cfg, FidelityPolicy::flow(), SchedKind::Wheel);
        fs.enable_cc(CcKind::None, &cfg);
        let a = fs.inject(0, 0, 2, 1_000_000);
        let b = fs.inject(0, 1, 2, 1_000_000);
        fs.run_to_completion();
        assert!(fs.cc_epochs > 0, "the epoch clock must tick");
        assert_eq!(fs.cc_kind(), Some(CcKind::None));
        assert_eq!((fs.finish_time(a), fs.finish_time(b)), base);
    }

    #[test]
    fn dcqcn_coupled_incast_marks_and_never_beats_fair_share() {
        let cfg = ss_cfg(5);
        let run = |kind: CcKind| {
            let mut fs = FlowSim::new(&cfg, FidelityPolicy::flow(), SchedKind::Wheel);
            fs.enable_cc(kind, &cfg);
            for s in 0..4usize {
                fs.inject(0, s, 4, 1_000_000);
            }
            fs.run_to_completion();
            let last = (0..4u32).map(|f| fs.finish_time(f).unwrap()).max().unwrap();
            (last, fs.cc_marks, fs.cc_epochs)
        };
        let (t_none, _, _) = run(CcKind::None);
        let (t_dcqcn, marks, epochs) = run(CcKind::Dcqcn);
        assert!(epochs > 0);
        // 4:1 incast overruns the shared kmin on the victim edge, so
        // the synthesized marks must fire
        assert!(marks > 0, "incast must cross the marking threshold");
        // symmetric flows get symmetric caps, and a cap never exceeds
        // the fair share's sustained throughput — DCQCN can only finish
        // at or after the uncapped fair-share time
        assert!(t_dcqcn >= t_none, "{t_dcqcn} vs {t_none}");
    }

    #[test]
    fn credit_starved_eqds_fluid_flow_completes() {
        // EQDS starts on a speculative-credit window; once consumed,
        // only epoch-tick grant pacing refills it. A fluid flow must
        // ride grants to completion rather than deadlock (ISSUE §6 —
        // the receiver-side hooks run from fluid epochs, no per-packet
        // cadence exists here).
        let cfg = ss_cfg(2);
        let mut fs = FlowSim::new(&cfg, FidelityPolicy::flow(), SchedKind::Wheel);
        fs.enable_cc(CcKind::Eqds, &cfg);
        let f = fs.inject(0, 0, 1, 1 << 20);
        fs.run_to_completion();
        assert!(fs.finish_time(f).is_some(), "grants must keep the flow moving");
        assert!(fs.cc_counter("cc_credits_granted") > 0, "epoch grants must be booked");
    }

    #[test]
    fn cc_coupled_wheel_heap_and_replay_agree() {
        let cfg = ft_cfg();
        let run = |sched: SchedKind| {
            let mut fs = FlowSim::new(&cfg, FidelityPolicy::hybrid(), sched);
            fs.enable_cc(CcKind::Swift, &cfg);
            for i in 0..12usize {
                fs.inject((i as u64) * 1_000, i, (i + 5) % 16, 200 * 1024 + i as u64 * 16 * 1024);
            }
            fs.fault(50_000, NetFault::LinkDown(16));
            fs.run_to_completion();
            (fs.drain_completions(), fs.resolves, fs.pkts_walked, fs.cc_epochs, fs.cc_marks)
        };
        let w = run(SchedKind::Wheel);
        assert!(w.3 > 0, "epochs must tick");
        assert_eq!(w, run(SchedKind::Heap), "wheel and heap must agree");
        assert_eq!(w, run(SchedKind::Wheel), "replay must be identical");
    }
}
