//! Cluster-wide metrics: hot-path counters plus named samples.

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::util::stats::Samples;

#[derive(Debug, Default)]
pub struct Metrics {
    // -- hot-path counters -------------------------------------------------
    pub pkts_sent: u64,
    pub pkts_delivered: u64,
    pub data_bytes_sent: u64,
    pub data_bytes_delivered: u64,
    pub pkts_dropped_queue: u64,
    pub pkts_dropped_corrupt: u64,
    /// Packets discarded by the receiver because their message already
    /// completed or timed out (OptiNIC late-packet handling, §3.1.1).
    pub pkts_dropped_stale: u64,
    pub retransmissions: u64,
    pub acks_sent: u64,
    pub nacks_sent: u64,
    pub cnps_sent: u64,
    pub pfc_pause_events: u64,
    pub pfc_paused_ns: u64,
    /// WQEs that completed via timeout with partial data (OptiNIC).
    pub partial_completions: u64,
    pub full_completions: u64,
    /// Messages preempted by a newer wqe_seq (OptiNIC early completion).
    pub preemptions: u64,
    /// Live transport-timer dispatches (stale generations excluded).
    pub timer_fires: u64,
    /// Generation-stamped timer entries dropped at fire time because the
    /// logical timer was re-armed or cancelled (lazy cancellation): these
    /// never dispatch into a transport.
    pub timer_stale_drops: u64,
    /// Coalesced egress serialization trains scheduled (host uplink +
    /// switch ports), and the packets they carried. Each train replaces
    /// `pkts − 1` per-packet serialization round-trips through the
    /// scheduler.
    pub tx_trains: u64,
    pub tx_train_pkts: u64,
    /// Buffers returned to the per-cluster freelists (train packet
    /// vectors + ctrl-message boxes) instead of being dropped — each one
    /// is a heap round-trip the hot path skipped.
    pub pool_recycles: u64,
    // -- named samples ------------------------------------------------------
    // §Perf: keyed by `&'static str` — per-event accounting must not
    // allocate, so hot counters pass literals and the maps never own keys.
    // Well-known named counters (surfaced under `counters` in `to_json`):
    // the CC plane's `cc_cnp_rx`, `cc_rtt_samples`, `cc_credits_granted`,
    // `cc_pacing_stalls` (see `cc::CcDriver`), the receive path's
    // `rx_srq_consumed` / `rx_no_recv_wqe`, and the fault campaign's
    // `faults_injected` / `faults_no_target`.
    samples: BTreeMap<&'static str, Samples>,
    counters: BTreeMap<&'static str, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn sample(&mut self, name: &'static str, value: f64) {
        self.samples.entry(name).or_default().push(value);
    }

    pub fn bump(&mut self, name: &'static str) {
        *self.counters.entry(name).or_insert(0) += 1;
    }

    pub fn add(&mut self, name: &'static str, v: u64) {
        *self.counters.entry(name).or_insert(0) += v;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn samples_mut(&mut self, name: &str) -> Option<&mut Samples> {
        self.samples.get_mut(name)
    }

    /// Fraction of data bytes that were sent but never delivered.
    pub fn loss_fraction(&self) -> f64 {
        if self.data_bytes_sent == 0 {
            0.0
        } else {
            1.0 - self.data_bytes_delivered as f64 / self.data_bytes_sent as f64
        }
    }

    /// Fold another partition's metrics into this one. Counters sum;
    /// sample reservoirs concatenate in call order. The partitioned
    /// engine merges shards in fixed partition order (0, 1, 2, …) so the
    /// merged `to_json` bytes are identical for any `--cores N` — the
    /// same discipline as the `--jobs` sweep merge.
    pub fn merge(&mut self, other: &Metrics) {
        self.pkts_sent += other.pkts_sent;
        self.pkts_delivered += other.pkts_delivered;
        self.data_bytes_sent += other.data_bytes_sent;
        self.data_bytes_delivered += other.data_bytes_delivered;
        self.pkts_dropped_queue += other.pkts_dropped_queue;
        self.pkts_dropped_corrupt += other.pkts_dropped_corrupt;
        self.pkts_dropped_stale += other.pkts_dropped_stale;
        self.retransmissions += other.retransmissions;
        self.acks_sent += other.acks_sent;
        self.nacks_sent += other.nacks_sent;
        self.cnps_sent += other.cnps_sent;
        self.pfc_pause_events += other.pfc_pause_events;
        self.pfc_paused_ns += other.pfc_paused_ns;
        self.partial_completions += other.partial_completions;
        self.full_completions += other.full_completions;
        self.preemptions += other.preemptions;
        self.timer_fires += other.timer_fires;
        self.timer_stale_drops += other.timer_stale_drops;
        self.tx_trains += other.tx_trains;
        self.tx_train_pkts += other.tx_train_pkts;
        self.pool_recycles += other.pool_recycles;
        for (k, v) in &other.counters {
            *self.counters.entry(k).or_insert(0) += v;
        }
        for (k, s) in &other.samples {
            self.samples.entry(k).or_default().merge(s);
        }
    }

    pub fn to_json(&mut self) -> Json {
        let mut o = Json::obj();
        o.set("pkts_sent", self.pkts_sent)
            .set("pkts_delivered", self.pkts_delivered)
            .set("data_bytes_sent", self.data_bytes_sent)
            .set("data_bytes_delivered", self.data_bytes_delivered)
            .set("pkts_dropped_queue", self.pkts_dropped_queue)
            .set("pkts_dropped_corrupt", self.pkts_dropped_corrupt)
            .set("pkts_dropped_stale", self.pkts_dropped_stale)
            .set("retransmissions", self.retransmissions)
            .set("acks_sent", self.acks_sent)
            .set("nacks_sent", self.nacks_sent)
            .set("cnps_sent", self.cnps_sent)
            .set("pfc_pause_events", self.pfc_pause_events)
            .set("partial_completions", self.partial_completions)
            .set("full_completions", self.full_completions)
            .set("preemptions", self.preemptions)
            .set("timer_fires", self.timer_fires)
            .set("timer_stale_drops", self.timer_stale_drops)
            .set("tx_trains", self.tx_trains)
            .set("tx_train_pkts", self.tx_train_pkts)
            .set("pool_recycles", self.pool_recycles)
            .set("loss_fraction", self.loss_fraction());
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters.set(k, *v);
        }
        o.set("counters", counters);
        let mut samples = Json::obj();
        let names: Vec<&'static str> = self.samples.keys().copied().collect();
        for name in names {
            let s = self.samples.get_mut(name).unwrap();
            if s.is_empty() {
                continue;
            }
            let mut e = Json::obj();
            e.set("count", s.len())
                .set("mean", s.mean())
                .set("p50", s.p50())
                .set("p99", s.p99())
                .set("max", s.max());
            samples.set(name, e);
        }
        o.set("samples", samples);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_samples() {
        let mut m = Metrics::new();
        m.bump("x");
        m.bump("x");
        m.add("y", 5);
        assert_eq!(m.counter("x"), 2);
        assert_eq!(m.counter("y"), 5);
        assert_eq!(m.counter("zzz"), 0);
        m.sample("lat", 1.0);
        m.sample("lat", 3.0);
        assert_eq!(m.samples_mut("lat").unwrap().len(), 2);
    }

    #[test]
    fn loss_fraction() {
        let mut m = Metrics::new();
        assert_eq!(m.loss_fraction(), 0.0);
        m.data_bytes_sent = 100;
        m.data_bytes_delivered = 97;
        assert!((m.loss_fraction() - 0.03).abs() < 1e-12);
    }

    #[test]
    fn merge_sums_counters_and_concatenates_samples() {
        let mut a = Metrics::new();
        a.pkts_sent = 3;
        a.pfc_paused_ns = 40; // not in to_json, still merged
        a.bump("x");
        a.sample("cct", 1.0);
        let mut b = Metrics::new();
        b.pkts_sent = 4;
        b.pfc_paused_ns = 2;
        b.bump("x");
        b.add("y", 7);
        b.sample("cct", 9.0);
        b.sample("tta", 5.0);
        a.merge(&b);
        assert_eq!(a.pkts_sent, 7);
        assert_eq!(a.pfc_paused_ns, 42);
        assert_eq!(a.counter("x"), 2);
        assert_eq!(a.counter("y"), 7);
        assert_eq!(a.samples_mut("cct").unwrap().len(), 2);
        assert_eq!(a.samples_mut("tta").unwrap().len(), 1);
    }

    #[test]
    fn json_export() {
        let mut m = Metrics::new();
        m.pkts_sent = 10;
        m.sample("cct", 5.0);
        let j = m.to_json();
        assert_eq!(j.get("pkts_sent").unwrap().as_i64(), Some(10));
        assert!(j.get("samples").unwrap().get("cct").is_some());
    }
}
