//! Cluster-wide metrics: hot-path counters plus named samples.

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::util::stats::Samples;

#[derive(Debug, Default)]
pub struct Metrics {
    // -- hot-path counters -------------------------------------------------
    pub pkts_sent: u64,
    pub pkts_delivered: u64,
    pub data_bytes_sent: u64,
    pub data_bytes_delivered: u64,
    pub pkts_dropped_queue: u64,
    pub pkts_dropped_corrupt: u64,
    /// Packets discarded by the receiver because their message already
    /// completed or timed out (OptiNIC late-packet handling, §3.1.1).
    pub pkts_dropped_stale: u64,
    pub retransmissions: u64,
    pub acks_sent: u64,
    pub nacks_sent: u64,
    pub cnps_sent: u64,
    pub pfc_pause_events: u64,
    pub pfc_paused_ns: u64,
    /// WQEs that completed via timeout with partial data (OptiNIC).
    pub partial_completions: u64,
    pub full_completions: u64,
    /// Messages preempted by a newer wqe_seq (OptiNIC early completion).
    pub preemptions: u64,
    /// Live transport-timer dispatches (stale generations excluded).
    pub timer_fires: u64,
    /// Generation-stamped timer entries dropped at fire time because the
    /// logical timer was re-armed or cancelled (lazy cancellation): these
    /// never dispatch into a transport.
    pub timer_stale_drops: u64,
    /// Coalesced egress serialization trains scheduled (host uplink +
    /// switch ports), and the packets they carried. Each train replaces
    /// `pkts − 1` per-packet serialization round-trips through the
    /// scheduler.
    pub tx_trains: u64,
    pub tx_train_pkts: u64,
    // -- named samples ------------------------------------------------------
    // §Perf: keyed by `&'static str` — per-event accounting must not
    // allocate, so hot counters pass literals and the maps never own keys.
    // Well-known named counters (surfaced under `counters` in `to_json`):
    // the CC plane's `cc_cnp_rx`, `cc_rtt_samples`, `cc_credits_granted`,
    // `cc_pacing_stalls` (see `cc::CcDriver`), the receive path's
    // `rx_srq_consumed` / `rx_no_recv_wqe`, and the fault campaign's
    // `faults_injected` / `faults_no_target`.
    samples: BTreeMap<&'static str, Samples>,
    counters: BTreeMap<&'static str, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn sample(&mut self, name: &'static str, value: f64) {
        self.samples.entry(name).or_default().push(value);
    }

    pub fn bump(&mut self, name: &'static str) {
        *self.counters.entry(name).or_insert(0) += 1;
    }

    pub fn add(&mut self, name: &'static str, v: u64) {
        *self.counters.entry(name).or_insert(0) += v;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn samples_mut(&mut self, name: &str) -> Option<&mut Samples> {
        self.samples.get_mut(name)
    }

    /// Fraction of data bytes that were sent but never delivered.
    pub fn loss_fraction(&self) -> f64 {
        if self.data_bytes_sent == 0 {
            0.0
        } else {
            1.0 - self.data_bytes_delivered as f64 / self.data_bytes_sent as f64
        }
    }

    pub fn to_json(&mut self) -> Json {
        let mut o = Json::obj();
        o.set("pkts_sent", self.pkts_sent)
            .set("pkts_delivered", self.pkts_delivered)
            .set("data_bytes_sent", self.data_bytes_sent)
            .set("data_bytes_delivered", self.data_bytes_delivered)
            .set("pkts_dropped_queue", self.pkts_dropped_queue)
            .set("pkts_dropped_corrupt", self.pkts_dropped_corrupt)
            .set("pkts_dropped_stale", self.pkts_dropped_stale)
            .set("retransmissions", self.retransmissions)
            .set("acks_sent", self.acks_sent)
            .set("nacks_sent", self.nacks_sent)
            .set("cnps_sent", self.cnps_sent)
            .set("pfc_pause_events", self.pfc_pause_events)
            .set("partial_completions", self.partial_completions)
            .set("full_completions", self.full_completions)
            .set("preemptions", self.preemptions)
            .set("timer_fires", self.timer_fires)
            .set("timer_stale_drops", self.timer_stale_drops)
            .set("tx_trains", self.tx_trains)
            .set("tx_train_pkts", self.tx_train_pkts)
            .set("loss_fraction", self.loss_fraction());
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters.set(k, *v);
        }
        o.set("counters", counters);
        let mut samples = Json::obj();
        let names: Vec<&'static str> = self.samples.keys().copied().collect();
        for name in names {
            let s = self.samples.get_mut(name).unwrap();
            if s.is_empty() {
                continue;
            }
            let mut e = Json::obj();
            e.set("count", s.len())
                .set("mean", s.mean())
                .set("p50", s.p50())
                .set("p99", s.p99())
                .set("max", s.max());
            samples.set(name, e);
        }
        o.set("samples", samples);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_samples() {
        let mut m = Metrics::new();
        m.bump("x");
        m.bump("x");
        m.add("y", 5);
        assert_eq!(m.counter("x"), 2);
        assert_eq!(m.counter("y"), 5);
        assert_eq!(m.counter("zzz"), 0);
        m.sample("lat", 1.0);
        m.sample("lat", 3.0);
        assert_eq!(m.samples_mut("lat").unwrap().len(), 2);
    }

    #[test]
    fn loss_fraction() {
        let mut m = Metrics::new();
        assert_eq!(m.loss_fraction(), 0.0);
        m.data_bytes_sent = 100;
        m.data_bytes_delivered = 97;
        assert!((m.loss_fraction() - 0.03).abs() < 1e-12);
    }

    #[test]
    fn json_export() {
        let mut m = Metrics::new();
        m.pkts_sent = 10;
        m.sample("cct", 5.0);
        let j = m.to_json();
        assert_eq!(j.get("pkts_sent").unwrap().as_i64(), Some(10));
        assert!(j.get("samples").unwrap().get("cct").is_some());
    }
}
