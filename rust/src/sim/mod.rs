//! Discrete-event simulation core: the virtual clock and the event queue.
//!
//! Everything in the L3 evaluation — transports, collectives, training runs,
//! tail-latency sweeps — executes inside this deterministic simulator.
//! Determinism contract: same seed + same config ⇒ bit-identical event
//! order (ties broken by insertion sequence number), independent of the
//! scheduler backend ([`SchedKind`]): the default hierarchical timing
//! wheel and the reference binary heap produce the same order bit for bit
//! (see `rust/tests/determinism.rs`).

pub mod cluster;
pub mod metrics;
pub mod scale;
pub mod sched;

pub use cluster::{AppCtx, Cluster, ClusterCfg, Event, EventSink, NicCtx};
pub use metrics::Metrics;
pub use scale::{run_scale_cell, ScaleCell, ScaleResult};
pub use sched::{EventKey, EventQueue, SchedKind};

/// Simulated time in nanoseconds.
pub type SimTime = u64;

pub const US: SimTime = 1_000;
pub const MS: SimTime = 1_000_000;
pub const SEC: SimTime = 1_000_000_000;

/// Pretty-print a simulated duration.
pub fn fmt_time(t: SimTime) -> String {
    crate::util::bench::fmt_ns(t as f64)
}
