//! Discrete-event simulation core: the virtual clock and the event queue.
//!
//! Everything in the L3 evaluation — transports, collectives, training runs,
//! tail-latency sweeps — executes inside this deterministic simulator.
//! Determinism contract: same seed + same config ⇒ bit-identical event
//! order (ties broken by insertion sequence number).

pub mod cluster;
pub mod metrics;

pub use cluster::{AppCtx, Cluster, ClusterCfg, Event, NicCtx};
pub use metrics::Metrics;

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Simulated time in nanoseconds.
pub type SimTime = u64;

pub const US: SimTime = 1_000;
pub const MS: SimTime = 1_000_000;
pub const SEC: SimTime = 1_000_000_000;

/// Pretty-print a simulated duration.
pub fn fmt_time(t: SimTime) -> String {
    crate::util::bench::fmt_ns(t as f64)
}

#[derive(Debug)]
struct Entry<E> {
    time: SimTime,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Min-heap event queue with deterministic FIFO tie-breaking.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
    pub scheduled: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            scheduled: 0,
        }
    }

    pub fn push(&mut self, time: SimTime, ev: E) {
        self.seq += 1;
        self.scheduled += 1;
        self.heap.push(Reverse(Entry {
            time,
            seq: self.seq,
            ev,
        }));
    }

    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|Reverse(e)| (e.time, e.ev))
    }

    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse(e)| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.push(5, 1);
        q.push(5, 2);
        q.push(5, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn peek_and_len() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(7, ());
        assert_eq!(q.peek_time(), Some(7));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn interleaved_push_pop_stays_sorted() {
        let mut q = EventQueue::new();
        q.push(10, 10u64);
        q.push(5, 5);
        assert_eq!(q.pop(), Some((5, 5)));
        q.push(3, 3);
        q.push(20, 20);
        assert_eq!(q.pop(), Some((3, 3)));
        assert_eq!(q.pop(), Some((10, 10)));
        assert_eq!(q.pop(), Some((20, 20)));
    }
}
