//! The cluster engine: hosts + NICs + fabric + transports + applications,
//! driven by one deterministic event loop.
//!
//! Ownership pattern: `Cluster` owns every component; event handlers take
//! the per-node transport/app out of its slot (`Option::take`), build a
//! context borrowing the *rest* of the cluster, dispatch, and put it back.
//! This gives components mutable access to shared state (memory pool, event
//! queue, metrics) without `Rc<RefCell>` on the hot path.
//!
//! Verbs v2 surface: applications receive typed [`CqEvent`]s through
//! [`App::on_cq_event`] and post work through [`Endpoint`] (obtained from
//! [`AppCtx::endpoint`]) using [`QpHandle`]s — single posts, doorbell-batched
//! posts, and shared-receive-queue posts. The engine drains completions with
//! the non-allocating `CompletionQueue::poll_into` into one reusable scratch
//! vector.

use crate::net::{
    BgTraffic, CtrlMsg, EnqueueOutcome, Fabric, FabricCfg, LinkDst, LinkId, NetFault,
    Packet, PktKind, SwitchCode,
};
use crate::sim::{EventQueue, Metrics, SchedKind, SimTime};
use crate::transport::{Transport, TransportCfg, TransportKind};
use crate::util::prng::Pcg64;
use crate::verbs::{
    CompletionQueue, CqEvent, Cqe, MemPool, NodeId, Qp, QpHandle, QpType, Qpn, Srq, Wqe,
};

use std::collections::{HashMap, VecDeque};

/// Default cap on packets coalesced into one egress serialization train
/// (`ClusterCfg::train_max`). Bounds both the per-event burst work and the
/// window in which a mid-train PFC pause cannot interrupt committed
/// packets (real NICs have the same in-flight burst exposure).
pub const TRAIN_MAX_DEFAULT: usize = 8;

/// One packet of a coalesced serialization train, with its finish time
/// reconstructed arithmetically at scheduling (start + cumulative
/// serialization delays).
#[derive(Debug)]
pub struct TrainPkt {
    pub pkt: Packet,
    pub done_at: SimTime,
}

/// Engine events.
#[derive(Debug)]
pub enum Event {
    /// Try to start serializing the next packet from a host NIC.
    HostTxKick(NodeId),
    /// Host NIC finished serializing `Packet` onto its uplink.
    HostTxDone(NodeId, Packet),
    /// Packet reached switch `sw`'s ingress (topology switch code: the
    /// single ToR is `0`; leaf–spine leaves come first, then spines).
    SwitchArrive { sw: SwitchCode, pkt: Packet },
    /// Egress link finished serializing `Packet`.
    PortTxDone(LinkId, Packet),
    /// First packet of a coalesced serialization train finished (host
    /// uplink when `port` is false — `idx` is the node — or a switch
    /// egress link when true — `idx` is the link). The remaining packets'
    /// finish times ride in the train, all `>=` this event's time — one
    /// scheduler round-trip per burst instead of one
    /// `HostTxDone`/`PortTxDone` per packet (§Perf).
    TxTrainDone {
        idx: usize,
        port: bool,
        train: Vec<TrainPkt>,
    },
    /// The link that carried a train frees at the LAST packet's finish
    /// time: clear busy and restart egress.
    TxTrainFree { idx: usize, port: bool },
    /// Packet delivered to a host NIC.
    HostRx(Packet),
    /// Transport-managed timer, stamped with the arming generation so
    /// re-armed/cancelled logical timers are dropped at fire time without
    /// dispatching into the transport (lazy cancellation).
    TransportTimer {
        node: NodeId,
        timer_id: u64,
        gen: u64,
    },
    /// Application wake-up (collective timeouts, compute completion, ...).
    AppWake { node: NodeId, token: u64 },
    /// Background-traffic flow arrival.
    BgArrival,
    /// One background packet hits a switch port queue.
    BgInject { port: NodeId, size: usize },
    /// Re-evaluate one edge port's PFC state (per-port pause/resume).
    PfcUpdate { link: LinkId },
    /// Queue-level deadline for a shared-receive-queue entry (verbs v2):
    /// if the entry is still waiting when this fires, it completes as
    /// `TimeoutFired` so an SRQ-only receiver can never be stranded by a
    /// wholly-lost message.
    SrqDeadline { node: NodeId, entry_id: u64 },
    /// SEU fault injection: corrupt random NIC state on a random node
    /// (behavioral fault-tolerance experiment, §2.4).
    InjectFault,
    /// Link-level fault action: flap, degrade, routing convergence
    /// (scenario builders live in `hw::fault`).
    NetFault(NetFault),
}

// ---- hot-path footprint guards (§Perf) -------------------------------------
// `Event` is pushed/popped for every simulated packet hop; its size is
// `Packet` (whose fattest variant is `Data(DataHdr)`) plus a word or two
// of variant framing. A regression here taxes every scheduler operation,
// so it fails the build loudly rather than showing up as a slow sweep.
const _: () = assert!(
    std::mem::size_of::<Event>() <= std::mem::size_of::<crate::net::Packet>() + 24
);
const _: () = assert!(std::mem::size_of::<Event>() <= 208);
const _: () = assert!(
    std::mem::size_of::<TrainPkt>() <= std::mem::size_of::<crate::net::Packet>() + 8
);

/// Per-node NIC front: egress queues ahead of the uplink.
#[derive(Debug, Default)]
pub struct Nic {
    /// Data-class egress (subject to PFC pause).
    pub data_q: VecDeque<Packet>,
    /// Control-class egress (ACK/CNP/credit/ctrl — never paused; this is
    /// how real deployments avoid PFC deadlocks on the ACK class).
    pub ctrl_q: VecDeque<Packet>,
    pub tx_busy: bool,
    /// Per-destination PFC pause state, indexed by destination host:
    /// set/cleared by that destination's edge port crossing XOFF/XON.
    /// (Pre-fix this was a single bool — one hot port paused every
    /// sender's entire data class.)
    pub paused_dsts: Vec<bool>,
    paused_since: Vec<SimTime>,
}

impl Nic {
    fn new(nodes: usize) -> Nic {
        Nic {
            paused_dsts: vec![false; nodes],
            paused_since: vec![0; nodes],
            ..Nic::default()
        }
    }

    /// Next packet eligible for the uplink: control class first (it
    /// bypasses PFC pause), then data. The data FIFO blocks on a paused
    /// HEAD — head-of-line within the sender queue is the realistic PFC
    /// cost — but an unpaused head flows even while other destinations
    /// are paused.
    fn pop_egress(&mut self) -> Option<Packet> {
        if let Some(p) = self.ctrl_q.pop_front() {
            return Some(p);
        }
        match self.data_q.front() {
            Some(p) if !self.paused_dsts[p.dst] => self.data_q.pop_front(),
            _ => None,
        }
    }

    /// Would `pop_egress` currently yield a packet?
    fn has_egress(&self) -> bool {
        !self.ctrl_q.is_empty()
            || self.data_q.front().is_some_and(|p| !self.paused_dsts[p.dst])
    }
}

/// Context handed to transports.
pub struct NicCtx<'a> {
    pub time: SimTime,
    pub node: NodeId,
    pub mem: &'a mut MemPool,
    pub cq: &'a mut CompletionQueue,
    pub metrics: &'a mut Metrics,
    pub rng: &'a mut Pcg64,
    events: &'a mut EventQueue<Event>,
    nic: &'a mut Nic,
    srq: &'a mut Srq,
    /// This node's armed transport timers: timer_id → live generation.
    timers: &'a mut HashMap<u64, u64>,
    /// Cluster-wide generation source (globally unique, so a consumed id
    /// can be re-armed without aliasing an old in-flight entry).
    timer_gen: &'a mut u64,
}

impl<'a> NicCtx<'a> {
    /// Queue a packet for transmission on this NIC's uplink.
    pub fn tx(&mut self, pkt: Packet) {
        debug_assert_eq!(pkt.src, self.node);
        let is_ctrl = !pkt.is_data();
        if let PktKind::Data(h) = &pkt.kind {
            self.metrics.data_bytes_sent += h.len as u64;
        }
        self.metrics.pkts_sent += 1;
        if is_ctrl {
            self.nic.ctrl_q.push_back(pkt);
        } else {
            self.nic.data_q.push_back(pkt);
        }
        // §Perf: kick only an idle NIC — a busy NIC re-kicks itself from
        // HostTxDone, so unconditional per-packet kicks just churn the
        // event heap (measurable on multi-MB collectives).
        if !self.nic.tx_busy {
            self.events.push(self.time, Event::HostTxKick(self.node));
        }
    }

    /// Arm — or re-arm — transport timer `timer_id` to fire after
    /// `delay`. Re-arming replaces the previous deadline: the superseded
    /// queue entry stays where it is and is dropped at fire time by its
    /// stale generation stamp (lazy cancellation), so re-arms are O(1)
    /// and stale fires never reach the transport.
    pub fn set_timer(&mut self, delay: SimTime, timer_id: u64) {
        *self.timer_gen += 1;
        let gen = *self.timer_gen;
        self.timers.insert(timer_id, gen);
        self.events.push(
            self.time + delay,
            Event::TransportTimer {
                node: self.node,
                timer_id,
                gen,
            },
        );
    }

    /// Disarm `timer_id`. Lazy: the scheduled entry is dropped when it
    /// fires. No-op if the timer is not armed.
    pub fn cancel_timer(&mut self, timer_id: u64) {
        self.timers.remove(&timer_id);
    }

    /// Push an internal wire CQE; it is converted to a typed `CqEvent` at
    /// the completion-queue boundary (apps never see `Cqe`).
    pub fn push_cqe(&mut self, cqe: Cqe) {
        self.cq.push_wire(cqe);
    }

    /// Pop the next shared-receive-queue entry, if any (SRQ fallback for
    /// two-sided messages arriving on a QP with an empty receive queue).
    pub fn pop_srq(&mut self) -> Option<Wqe> {
        self.srq.pop()
    }
}

/// Context handed to applications (collective engines, drivers). Verbs
/// operations live on [`Endpoint`] (see [`AppCtx::endpoint`]); this struct
/// keeps the non-verbs utilities (memory, wake-ups, control plane).
pub struct AppCtx<'a> {
    pub time: SimTime,
    pub node: NodeId,
    pub mem: &'a mut MemPool,
    pub metrics: &'a mut Metrics,
    pub rng: &'a mut Pcg64,
    events: &'a mut EventQueue<Event>,
    nic: &'a mut Nic,
    transport: &'a mut dyn Transport,
    cq: &'a mut CompletionQueue,
    srq: &'a mut Srq,
    timers: &'a mut HashMap<u64, u64>,
    timer_gen: &'a mut u64,
    base_rtt_ns: u64,
}

impl<'a> AppCtx<'a> {
    /// The verbs v2 posting surface for this node's NIC.
    pub fn endpoint(&mut self) -> Endpoint<'_, 'a> {
        Endpoint { ctx: self }
    }

    /// Schedule an application wake-up.
    pub fn wake_in(&mut self, delay: SimTime, token: u64) {
        self.events.push(
            self.time + delay,
            Event::AppWake {
                node: self.node,
                token,
            },
        );
    }

    /// Send a reliable control-plane message (handshakes, timeout stats).
    /// Delivered after one-way base latency + negligible serialization —
    /// the paper's "pre-existing reliable channel" (§3.1.2).
    pub fn send_ctrl(&mut self, to: NodeId, msg: CtrlMsg) {
        let pkt = Packet::ctrl(self.node, to, msg);
        // reliable channel: bypasses the lossy data fabric
        self.events
            .push(self.time + self.base_rtt_ns / 2, Event::HostRx(pkt));
    }

    pub fn base_rtt_ns(&self) -> u64 {
        self.base_rtt_ns
    }
}

/// The verbs v2 posting handle: typed [`QpHandle`]s, doorbell-batched
/// posts, and the node's shared receive queue. Borrowed from an
/// [`AppCtx`] for the duration of the posting calls.
pub struct Endpoint<'c, 'a> {
    ctx: &'c mut AppCtx<'a>,
}

impl<'c, 'a> Endpoint<'c, 'a> {
    /// Post one send WQE (rings one doorbell; prefer
    /// [`Endpoint::post_send_batch`] when posting several).
    pub fn post_send(&mut self, qp: QpHandle, wqe: Wqe) {
        let (transport, mut nic_ctx) = split_ctx(self.ctx);
        transport.post_send(&mut nic_ctx, qp.qpn, wqe);
    }

    /// Post one receive WQE on a specific QP.
    pub fn post_recv(&mut self, qp: QpHandle, wqe: Wqe) {
        let (transport, mut nic_ctx) = split_ctx(self.ctx);
        transport.post_recv(&mut nic_ctx, qp.qpn, wqe);
    }

    /// Post many send WQEs with one doorbell per touched QP.
    pub fn post_send_batch(&mut self, posts: impl IntoIterator<Item = (QpHandle, Wqe)>) {
        let batch: Vec<(Qpn, Wqe)> =
            posts.into_iter().map(|(h, w)| (h.qpn, w)).collect();
        if batch.is_empty() {
            return;
        }
        let (transport, mut nic_ctx) = split_ctx(self.ctx);
        transport.post_send_batch(&mut nic_ctx, batch);
    }

    /// Post many receive WQEs in one engine crossing.
    pub fn post_recv_batch(&mut self, posts: impl IntoIterator<Item = (QpHandle, Wqe)>) {
        let batch: Vec<(Qpn, Wqe)> =
            posts.into_iter().map(|(h, w)| (h.qpn, w)).collect();
        if batch.is_empty() {
            return;
        }
        let (transport, mut nic_ctx) = split_ctx(self.ctx);
        transport.post_recv_batch(&mut nic_ctx, batch);
    }

    /// Post a receive WQE to the node's shared receive queue: any QP whose
    /// own RQ is empty consumes SRQ entries in FIFO order. If the WQE
    /// carries a timeout, a queue-level deadline is armed immediately — an
    /// entry still unconsumed when it fires completes as `TimeoutFired`
    /// (a wholly-lost message must not strand the receiver).
    pub fn post_srq_recv(&mut self, wqe: Wqe) {
        let deadline = wqe.timeout;
        let entry_id = self.ctx.srq.post(wqe);
        if let Some(t) = deadline {
            self.ctx.events.push(
                self.ctx.time + t,
                Event::SrqDeadline {
                    node: self.ctx.node,
                    entry_id,
                },
            );
        }
    }

    /// Batch-post SRQ entries.
    pub fn post_srq_recv_batch(&mut self, posts: impl IntoIterator<Item = Wqe>) {
        for wqe in posts {
            self.post_srq_recv(wqe);
        }
    }

    /// Entries currently waiting in the shared receive queue.
    pub fn srq_len(&self) -> usize {
        self.ctx.srq.len()
    }
}

/// Reborrow an `AppCtx` into the transport reference plus a `NicCtx` over
/// the remaining shared state (disjoint fields, so both can be mutable).
fn split_ctx<'c, 'a>(ctx: &'c mut AppCtx<'a>) -> (&'c mut dyn Transport, NicCtx<'c>) {
    let nic_ctx = NicCtx {
        time: ctx.time,
        node: ctx.node,
        mem: &mut *ctx.mem,
        cq: &mut *ctx.cq,
        metrics: &mut *ctx.metrics,
        rng: &mut *ctx.rng,
        events: &mut *ctx.events,
        nic: &mut *ctx.nic,
        srq: &mut *ctx.srq,
        timers: &mut *ctx.timers,
        timer_gen: &mut *ctx.timer_gen,
    };
    (&mut *ctx.transport, nic_ctx)
}

/// An application running on every node (one instance per rank).
pub trait App {
    fn on_start(&mut self, ctx: &mut AppCtx);
    /// A typed, loss-aware completion event (verbs v2). Raw CQEs never
    /// reach applications.
    fn on_cq_event(&mut self, ctx: &mut AppCtx, ev: CqEvent);
    fn on_wake(&mut self, ctx: &mut AppCtx, token: u64);
    fn on_ctrl(&mut self, ctx: &mut AppCtx, from: NodeId, msg: CtrlMsg);
    fn is_done(&self) -> bool;
    /// Downcast support so drivers can extract results after a run.
    fn as_any(&mut self) -> &mut dyn std::any::Any;
}

/// Cluster configuration.
#[derive(Clone, Debug)]
pub struct ClusterCfg {
    pub fabric: FabricCfg,
    pub transport: TransportKind,
    pub transport_cfg: TransportCfg,
    pub bg_load: f64,
    pub seed: u64,
    /// Hard wall: the run aborts (returning what happened so far) if the
    /// clock passes this. Guards against protocol deadlocks in experiments.
    pub max_sim_time: SimTime,
    /// Event scheduler backend. The timing wheel is the default; the
    /// reference heap stays selectable for A/B parity testing (both yield
    /// bit-identical event order — see `rust/tests/determinism.rs`).
    pub scheduler: SchedKind,
    /// Max packets coalesced into one egress serialization train (host
    /// uplink and switch downlink). `1` restores one serialization event
    /// per packet (the pre-train engine behavior, kept for comparison).
    pub train_max: usize,
    /// Per-rank compute-delay injection (straggler choreography): rank
    /// `r`'s workload start is postponed by `compute_delays[r]` ns on top
    /// of any spec-level start delay. Empty = no stragglers. The scenario
    /// subsystem drives this so a straggler rides along with ANY workload
    /// run on the cluster, not just collectives that plumb their own
    /// `start_delays` (docs/SCENARIOS.md §Stragglers).
    pub compute_delays: Vec<SimTime>,
}

impl ClusterCfg {
    pub fn new(fabric: FabricCfg, transport: TransportKind) -> ClusterCfg {
        let transport_cfg = TransportCfg::from_fabric(&fabric);
        ClusterCfg {
            fabric,
            transport,
            transport_cfg,
            bg_load: 0.0,
            seed: 1,
            max_sim_time: 120 * crate::sim::SEC,
            scheduler: SchedKind::Wheel,
            train_max: TRAIN_MAX_DEFAULT,
            compute_delays: Vec::new(),
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_bg_load(mut self, load: f64) -> Self {
        self.bg_load = load;
        self
    }

    pub fn with_scheduler(mut self, scheduler: SchedKind) -> Self {
        self.scheduler = scheduler;
        self
    }

    pub fn with_train_max(mut self, train_max: usize) -> Self {
        self.train_max = train_max.max(1);
        self
    }

    /// Select the CC algorithm as an explicit experiment choice: the
    /// transports must not substitute their paper-default scheme (CC
    /// ablations and the `cc_sweep` grid run through this).
    pub fn with_cc(mut self, cc: crate::cc::CcKind) -> Self {
        self.transport_cfg.cc = cc;
        self.transport_cfg.cc_forced = true;
        self
    }

    /// Inject per-rank compute delays (straggler choreography).
    pub fn with_compute_delays(mut self, delays: Vec<SimTime>) -> Self {
        self.compute_delays = delays;
        self
    }
}

/// The simulated cluster.
pub struct Cluster {
    pub cfg: ClusterCfg,
    pub time: SimTime,
    pub events: EventQueue<Event>,
    pub fabric: Fabric,
    pub mem: MemPool,
    pub metrics: Metrics,
    pub rng: Pcg64,
    nics: Vec<Nic>,
    cqs: Vec<CompletionQueue>,
    srqs: Vec<Srq>,
    transports: Vec<Option<Box<dyn Transport>>>,
    apps: Vec<Option<Box<dyn App>>>,
    bg: Option<BgTraffic>,
    pfc_required: bool,
    next_qpn: u32,
    pub events_processed: u64,
    /// Reusable completion-drain buffer (verbs v2 `poll_into` hot loop).
    cq_scratch: Vec<CqEvent>,
    /// Per-node armed transport timers (timer_id → live generation) for
    /// generation-stamped lazy cancellation.
    timers: Vec<HashMap<u64, u64>>,
    /// Cluster-global timer generation source.
    timer_gen: u64,
    /// An app was dispatched since the last completion poll (§Perf: gates
    /// the O(nodes) `apps_done` scan in the run loop).
    apps_dirty: bool,
}

impl Cluster {
    pub fn new(mut cfg: ClusterCfg) -> Cluster {
        // the engine keeps its own copy of the fabric cfg for host-side
        // serialization — heal the cached integer rate here too, so a
        // caller who wrote `fab.link_gbps = …` directly can never run
        // host links and switch ports at different rates
        cfg.fabric.ser_ps_per_byte = crate::net::ps_per_byte(cfg.fabric.link_gbps);
        let nodes = cfg.fabric.nodes;
        let mut rng = Pcg64::new(cfg.seed, 0xc1u64);
        let fabric = Fabric::new(cfg.fabric.clone());
        let transports: Vec<Option<Box<dyn Transport>>> = (0..nodes)
            .map(|n| Some(cfg.transport.build(n, &cfg.transport_cfg)))
            .collect();
        let pfc_required = transports[0].as_ref().unwrap().requires_pfc();
        let bg = if cfg.bg_load > 0.0 {
            Some(BgTraffic::new(
                crate::net::traffic::BgTrafficCfg {
                    load: cfg.bg_load,
                    ..Default::default()
                },
                nodes,
                cfg.fabric.link_gbps,
                rng.fork(0xb6),
            ))
        } else {
            None
        };
        let mut c = Cluster {
            time: 0,
            events: EventQueue::with_kind(cfg.scheduler),
            fabric,
            mem: MemPool::new(),
            metrics: Metrics::new(),
            rng,
            nics: (0..nodes).map(|_| Nic::new(nodes)).collect(),
            cqs: (0..nodes).map(|_| CompletionQueue::default()).collect(),
            srqs: (0..nodes).map(|_| Srq::default()).collect(),
            transports,
            apps: (0..nodes).map(|_| None).collect(),
            bg,
            pfc_required,
            next_qpn: 1,
            events_processed: 0,
            cq_scratch: Vec::with_capacity(64),
            timers: (0..nodes).map(|_| HashMap::new()).collect(),
            timer_gen: 0,
            apps_dirty: false,
            cfg,
        };
        if let Some(bg) = &c.bg {
            c.events.push(bg.next_arrival_ns, Event::BgArrival);
        }
        c
    }

    pub fn nodes(&self) -> usize {
        self.cfg.fabric.nodes
    }

    /// Create a connected QP pair between two nodes; returns the typed
    /// handles (`a`'s end, `b`'s end) applications post through.
    pub fn connect(&mut self, a: NodeId, b: NodeId, qp_type: QpType) -> (QpHandle, QpHandle) {
        let qpn_a = self.next_qpn;
        let qpn_b = self.next_qpn + 1;
        self.next_qpn += 2;
        let mtu = self.cfg.transport_cfg.mtu;
        self.transports[a].as_mut().unwrap().create_qp(Qp {
            qpn: qpn_a,
            qp_type,
            peer_node: b,
            peer_qpn: qpn_b,
            mtu,
        });
        self.transports[b].as_mut().unwrap().create_qp(Qp {
            qpn: qpn_b,
            qp_type,
            peer_node: a,
            peer_qpn: qpn_a,
            mtu,
        });
        (
            QpHandle { qpn: qpn_a, peer: b },
            QpHandle { qpn: qpn_b, peer: a },
        )
    }

    /// Entries consumed from a node's shared receive queue so far.
    pub fn srq_consumed(&self, node: NodeId) -> u64 {
        self.srqs[node].consumed
    }

    /// Install the application for a node.
    pub fn set_app(&mut self, node: NodeId, app: Box<dyn App>) {
        self.apps[node] = Some(app);
    }

    /// Take an app back out (to read results after a run).
    pub fn take_app(&mut self, node: NodeId) -> Option<Box<dyn App>> {
        self.apps[node].take()
    }

    pub fn transport(&self, node: NodeId) -> &dyn Transport {
        self.transports[node].as_deref().unwrap()
    }

    pub fn transport_mut(&mut self, node: NodeId) -> &mut dyn Transport {
        self.transports[node].as_deref_mut().unwrap()
    }

    /// Start all installed apps (schedules their `on_start` at current time).
    pub fn start_apps(&mut self) {
        for node in 0..self.nodes() {
            if self.apps[node].is_some() {
                // token u64::MAX is reserved as the start signal
                self.events.push(
                    self.time,
                    Event::AppWake {
                        node,
                        token: u64::MAX,
                    },
                );
            }
        }
    }

    /// Run until all apps report done, the queue drains, or limits hit.
    /// Returns true if all apps completed.
    pub fn run(&mut self) -> bool {
        let max_time = self.cfg.max_sim_time;
        // §Perf: `apps_done` is O(nodes) dyn calls — poll it only after
        // events that actually dispatched into an app (`apps_dirty`), not
        // before every event pop.
        if self.apps_done() {
            return true;
        }
        loop {
            let Some((t, ev)) = self.events.pop() else {
                return self.apps_done();
            };
            debug_assert!(t >= self.time, "time went backwards");
            self.time = t;
            if self.time > max_time {
                log::warn!("simulation wall hit at {}", crate::sim::fmt_time(max_time));
                return false;
            }
            self.events_processed += 1;
            self.handle(ev);
            if self.apps_dirty {
                self.apps_dirty = false;
                if self.apps_done() {
                    return true;
                }
            }
        }
    }

    /// Keep processing events up to absolute time `t` even after all apps
    /// report done — lets callers drain in-flight packets (e.g. one-sided
    /// WRITEs whose sender completed on transmit).
    pub fn run_until(&mut self, t: SimTime) {
        while let Some(next) = self.events.peek_time() {
            if next > t {
                break;
            }
            let (ts, ev) = self.events.pop().unwrap();
            self.time = ts;
            self.events_processed += 1;
            self.handle(ev);
        }
        self.time = self.time.max(t.min(self.time + 1));
    }

    fn apps_done(&self) -> bool {
        self.apps
            .iter()
            .all(|a| a.as_ref().map(|a| a.is_done()).unwrap_or(true))
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::HostTxKick(node) => self.host_tx_kick(node),
            Event::HostTxDone(node, pkt) => {
                self.nics[node].tx_busy = false;
                let arrive = self.time + self.cfg.fabric.prop_delay_ns;
                let sw = self.fabric.topo.ingress_switch(node);
                self.events.push(arrive, Event::SwitchArrive { sw, pkt });
                self.events.push(self.time, Event::HostTxKick(node));
            }
            Event::SwitchArrive { sw, pkt } => self.switch_arrive(sw, pkt),
            Event::PortTxDone(link, pkt) => self.port_tx_done(link, pkt),
            Event::TxTrainDone { idx, port, train } => {
                self.tx_train_done(idx, port, train)
            }
            Event::TxTrainFree { idx, port } => {
                if port {
                    self.fabric.ports[idx].busy = false;
                    self.port_start_tx(idx);
                    self.maybe_pfc_update(idx);
                } else {
                    self.nics[idx].tx_busy = false;
                    self.host_tx_kick(idx);
                }
            }
            Event::HostRx(pkt) => self.host_rx(pkt),
            Event::TransportTimer { node, timer_id, gen } => {
                if self.timers[node].get(&timer_id) == Some(&gen) {
                    self.timers[node].remove(&timer_id);
                    self.metrics.timer_fires += 1;
                    self.with_transport(node, |t, ctx| t.on_timer(ctx, timer_id));
                    self.drain_cqes(node);
                } else {
                    // re-armed or cancelled since scheduling: drop here,
                    // never dispatch (generation-stamped lazy cancellation)
                    self.metrics.timer_stale_drops += 1;
                }
            }
            Event::AppWake { node, token } => {
                if token == u64::MAX {
                    self.with_app(node, |a, ctx| a.on_start(ctx));
                } else {
                    self.with_app(node, |a, ctx| a.on_wake(ctx, token));
                }
                self.drain_cqes(node);
            }
            Event::BgArrival => self.bg_arrival(),
            Event::BgInject { port, size } => self.bg_inject(port, size),
            Event::PfcUpdate { link } => self.pfc_update(link),
            Event::NetFault(fault) => self.net_fault(fault),
            Event::SrqDeadline { node, entry_id } => {
                // entry already consumed by an arriving message ⇒ no-op;
                // its fate is the per-message deadline armed at activation
                if let Some(wqe) = self.srqs[node].remove(entry_id) {
                    self.metrics.partial_completions += 1;
                    self.cqs[node].push_event(CqEvent::TimeoutFired {
                        wr_id: wqe.wr_id,
                        qpn: 0, // queue-level: the entry never bound to a QP
                        is_recv: true,
                        delivered_bytes: 0,
                        expected_bytes: wqe.total_len(),
                        time: self.time,
                    });
                    self.drain_cqes(node);
                }
            }
            Event::InjectFault => {
                let node = self.rng.index(self.nodes());
                let mut t = self.transports[node].take().expect("transport");
                let desc = t.inject_fault(&mut self.rng);
                self.transports[node] = Some(t);
                if let Some(d) = desc {
                    log::debug!("fault injected @{}: {d}", crate::sim::fmt_time(self.time));
                    self.metrics.bump("faults_injected");
                } else {
                    self.metrics.bump("faults_no_target");
                }
            }
        }
    }

    /// Schedule an SEU-style fault injection at an absolute sim time.
    pub fn schedule_fault(&mut self, at: SimTime) {
        self.events.push(at, Event::InjectFault);
    }

    /// Total QPs currently stalled across all NICs.
    pub fn total_stalled_qps(&self) -> usize {
        self.transports
            .iter()
            .map(|t| t.as_ref().map(|t| t.stalled_qps()).unwrap_or(0))
            .sum()
    }

    // ---- host NIC egress ---------------------------------------------------

    fn host_tx_kick(&mut self, node: NodeId) {
        let train_max = self.cfg.train_max.max(1);
        let nic = &mut self.nics[node];
        if nic.tx_busy {
            return;
        }
        let Some(first) = nic.pop_egress() else { return };
        nic.tx_busy = true;
        let mut done = self.time + self.cfg.fabric.serialize_ns(first.size);
        if train_max <= 1 || !nic.has_egress() {
            // single packet: classic per-packet serialization round-trip
            self.events.push(done, Event::HostTxDone(node, first));
            return;
        }
        // §Perf: coalesce back-to-back egress into one packet train — one
        // scheduler round-trip for the burst instead of a HostTxDone +
        // re-kick per packet; per-packet finish times are reconstructed
        // arithmetically from cumulative serialization delays.
        let first_done = done;
        let mut train = Vec::with_capacity(train_max.min(16));
        train.push(TrainPkt {
            pkt: first,
            done_at: done,
        });
        while train.len() < train_max {
            let Some(p) = nic.pop_egress() else { break };
            done += self.cfg.fabric.serialize_ns(p.size);
            train.push(TrainPkt {
                pkt: p,
                done_at: done,
            });
        }
        self.metrics.tx_trains += 1;
        self.metrics.tx_train_pkts += train.len() as u64;
        self.events.push(
            first_done,
            Event::TxTrainDone {
                idx: node,
                port: false,
                train,
            },
        );
    }

    /// A serialization train's first packet finished: emit every packet's
    /// downstream event at its reconstructed time (all >= now), then free
    /// the link at the last packet's finish time.
    fn tx_train_done(&mut self, idx: usize, port: bool, train: Vec<TrainPkt>) {
        let prop = self.cfg.fabric.prop_delay_ns;
        let mut last = self.time;
        if port {
            for tp in train {
                last = tp.done_at;
                // per-packet corruption/jitter in train order keeps RNG
                // consumption deterministic
                self.forward_from(idx, tp.done_at, tp.pkt);
            }
        } else {
            let sw = self.fabric.topo.ingress_switch(idx);
            for tp in train {
                last = tp.done_at;
                self.events
                    .push(tp.done_at + prop, Event::SwitchArrive { sw, pkt: tp.pkt });
            }
        }
        self.events.push(last, Event::TxTrainFree { idx, port });
    }

    // ---- switch ------------------------------------------------------------

    /// A packet hit switch `sw`'s ingress: route it to its next-hop
    /// egress link (ECMP/spray happens inside `Fabric::route`) and queue.
    fn switch_arrive(&mut self, sw: SwitchCode, pkt: Packet) {
        let link = self.fabric.route(sw, &pkt, &mut self.rng);
        let was_idle = !self.fabric.ports[link].busy;
        match self.fabric.enqueue(link, pkt, &mut self.rng) {
            EnqueueOutcome::Dropped => {
                // attribute the loss: a dead link's blackhole is a fault
                // effect, not a congestion drop — fault experiments read
                // these as separate causes
                if self.fabric.ports[link].up {
                    self.metrics.pkts_dropped_queue += 1;
                } else {
                    self.metrics.add("pkts_dropped_link_down", 1);
                }
            }
            EnqueueOutcome::Queued { .. } => {
                if was_idle {
                    self.port_start_tx(link);
                }
            }
        }
        self.maybe_pfc_update(link);
    }

    /// A packet finished serializing on `link` at `done_at`: deliver it
    /// downstream — to the host NIC (after the corruption lottery + the
    /// single-tier spray-jitter stand-in) or to the next switch tier.
    fn forward_from(&mut self, link: LinkId, done_at: SimTime, pkt: Packet) {
        let prop = self.cfg.fabric.prop_delay_ns;
        match self.fabric.link_dst(link) {
            LinkDst::Host(_) => {
                if self.fabric.corrupted(&pkt, &mut self.rng) {
                    self.metrics.pkts_dropped_corrupt += 1;
                    return;
                }
                let jitter = self.fabric.spray_delay(&pkt, &mut self.rng);
                self.events.push(done_at + prop + jitter, Event::HostRx(pkt));
            }
            LinkDst::Leaf(l) => {
                let sw = self.fabric.topo.sw_leaf(l);
                self.events.push(done_at + prop, Event::SwitchArrive { sw, pkt });
            }
            LinkDst::Spine(s) => {
                let sw = self.fabric.topo.sw_spine(s);
                self.events.push(done_at + prop, Event::SwitchArrive { sw, pkt });
            }
            LinkDst::Core(c) => {
                let sw = self.fabric.topo.sw_core(c);
                self.events.push(done_at + prop, Event::SwitchArrive { sw, pkt });
            }
        }
    }

    /// Schedule a per-port PFC re-evaluation only when that edge port
    /// crossed a threshold — unconditional per-packet scheduling floods
    /// the event queue, and core ports rely on ECN/drops rather than PFC
    /// (docs/TOPOLOGY.md §PFC).
    fn maybe_pfc_update(&mut self, link: LinkId) {
        if !self.pfc_required || !self.fabric.topo.is_edge(link) {
            return;
        }
        let asserted = self.fabric.ports[link].pfc_asserted;
        if (!asserted && self.fabric.pfc_should_pause(link))
            || (asserted && self.fabric.pfc_should_resume(link))
        {
            self.events.push(self.time, Event::PfcUpdate { link });
        }
    }

    fn port_start_tx(&mut self, link: LinkId) {
        let train_max = self.cfg.train_max.max(1);
        let mbps = self.fabric.link_mbps(link);
        let qlen = self.fabric.queue_bytes(link);
        let Some(mut pkt) = self.fabric.dequeue(link) else {
            self.fabric.ports[link].busy = false;
            return;
        };
        // stamp/accumulate the uniform telemetry header (NetHints) on
        // data packets: bottleneck queue depth, CE mark, port busy-time
        // proxy, link rate — the one code path every CC scheme's in-band
        // signals come from
        Fabric::stamp_hints(&mut pkt, qlen, self.fabric.ports[link].tx_bytes, mbps);
        self.fabric.ports[link].busy = true;
        let mut done = self.time + self.fabric.port_tx_ns(link, &pkt);
        if train_max <= 1 || self.fabric.ports[link].queue.is_empty() {
            self.events.push(done, Event::PortTxDone(link, pkt));
            return;
        }
        // §Perf: train the egress too — dequeue the burst now with
        // arithmetic finish times (switch delay + serialization each);
        // telemetry is stamped from the residual queue before each
        // packet's own dequeue, approximating the staggered drain.
        let first_done = done;
        let mut train = Vec::with_capacity(train_max.min(16));
        train.push(TrainPkt { pkt, done_at: done });
        while train.len() < train_max {
            let qlen = self.fabric.queue_bytes(link);
            let Some(mut pkt) = self.fabric.dequeue(link) else { break };
            Fabric::stamp_hints(&mut pkt, qlen, self.fabric.ports[link].tx_bytes, mbps);
            done += self.fabric.port_tx_ns(link, &pkt);
            train.push(TrainPkt { pkt, done_at: done });
        }
        self.metrics.tx_trains += 1;
        self.metrics.tx_train_pkts += train.len() as u64;
        self.events.push(
            first_done,
            Event::TxTrainDone {
                idx: link,
                port: true,
                train,
            },
        );
    }

    fn port_tx_done(&mut self, link: LinkId, pkt: Packet) {
        // next packet on this link
        self.fabric.ports[link].busy = false;
        self.port_start_tx(link);
        self.maybe_pfc_update(link);
        self.forward_from(link, self.time, pkt);
    }

    // ---- host NIC ingress ----------------------------------------------------

    fn host_rx(&mut self, pkt: Packet) {
        let node = pkt.dst;
        match pkt.kind {
            PktKind::Pause { xoff, for_dst } => {
                let nic = &mut self.nics[node];
                if xoff && !nic.paused_dsts[for_dst] {
                    nic.paused_dsts[for_dst] = true;
                    nic.paused_since[for_dst] = self.time;
                    self.metrics.pfc_pause_events += 1;
                } else if !xoff && nic.paused_dsts[for_dst] {
                    nic.paused_dsts[for_dst] = false;
                    self.metrics.pfc_paused_ns += self.time - nic.paused_since[for_dst];
                    self.events.push(self.time, Event::HostTxKick(node));
                }
            }
            PktKind::Bg => { /* other tenants' traffic: sunk */ }
            PktKind::Ctrl(msg) => {
                let from = pkt.src;
                self.with_app(node, |a, ctx| a.on_ctrl(ctx, from, *msg));
                self.drain_cqes(node);
            }
            _ => {
                if let PktKind::Data(h) = &pkt.kind {
                    self.metrics.pkts_delivered += 1;
                    let _ = h;
                }
                self.with_transport(node, |t, ctx| t.on_packet(ctx, pkt));
                self.drain_cqes(node);
            }
        }
    }

    // ---- PFC ------------------------------------------------------------------

    /// Per-port PFC transition: assert when THIS edge port crossed XOFF,
    /// release when it drained below XON. (Pre-fix, one global flag keyed
    /// on `any`/`all` ports paused every sender in the cluster — the
    /// head-of-line amplification this PR removes.)
    fn pfc_update(&mut self, link: LinkId) {
        let asserted = self.fabric.ports[link].pfc_asserted;
        if !asserted && self.fabric.pfc_should_pause(link) {
            self.fabric.ports[link].pfc_asserted = true;
            self.fabric.pfc_pauses += 1;
            self.broadcast_pause(link, true);
        } else if asserted && self.fabric.pfc_should_resume(link) {
            self.fabric.ports[link].pfc_asserted = false;
            self.broadcast_pause(link, false);
        }
    }

    /// Deliver per-destination pause/resume frames: every host learns the
    /// state of destination `for_dst` (edge link id == host id), but only
    /// traffic actually headed there blocks at the sender FIFO.
    fn broadcast_pause(&mut self, for_dst: NodeId, xoff: bool) {
        for node in 0..self.nodes() {
            let pkt = Packet {
                src: node, // nominal
                dst: node,
                size: 64,
                ecn: false,
                spray: false,
                kind: PktKind::Pause { xoff, for_dst },
            };
            self.events
                .push(self.time + self.cfg.fabric.prop_delay_ns, Event::HostRx(pkt));
        }
    }

    // ---- link-level faults ----------------------------------------------------

    /// Apply a link-level fault. `LinkDown` schedules its own routing
    /// convergence (`RerouteOut` after `reroute_ns`); until that fires,
    /// ECMP/spray keep hashing flows onto the dead link — the
    /// pre-convergence blackhole window real fabrics suffer.
    fn net_fault(&mut self, fault: NetFault) {
        match fault {
            NetFault::LinkDown(link) => {
                let flushed = self.fabric.link_down(link);
                if flushed > 0 {
                    self.metrics.add("pkts_dropped_link_down", flushed as u64);
                }
                self.metrics.bump("net_faults");
                self.events.push(
                    self.time + self.cfg.fabric.reroute_ns,
                    Event::NetFault(NetFault::RerouteOut(link)),
                );
                // a downed edge port just emptied: release any PFC it held
                self.maybe_pfc_update(link);
            }
            NetFault::LinkUp(link) => {
                self.fabric.link_up(link);
                self.metrics.bump("net_faults");
                if !self.fabric.ports[link].busy && !self.fabric.ports[link].queue.is_empty()
                {
                    self.port_start_tx(link);
                }
            }
            NetFault::RerouteOut(link) => self.fabric.reroute_out(link),
            NetFault::Degrade(link, factor) => {
                self.fabric.degrade_link(link, factor);
                self.metrics.bump("net_faults");
            }
        }
    }

    /// Schedule a link-level fault at an absolute sim time (scenario
    /// builders — flap, spine failure, degrade — live in `hw::fault`).
    pub fn schedule_net_fault(&mut self, at: SimTime, fault: NetFault) {
        self.events.push(at, Event::NetFault(fault));
    }

    /// Choreographed incast microburst: `bytes` of cross-traffic converge
    /// on `dst`'s edge port from `at` on, as back-to-back `pkt_size`
    /// packets. Rides the background-traffic injection path
    /// (`Event::BgInject`), so the burst contends for queue space and
    /// bandwidth like any other tenant — and obeys PFC and the
    /// deep-queue backoff the same way. Consumes no RNG at scheduling
    /// time: the burst is part of the deterministic event schedule.
    pub fn schedule_incast(&mut self, at: SimTime, dst: NodeId, bytes: usize, pkt_size: usize) {
        let pkt = pkt_size.max(256);
        let mut off: SimTime = 0;
        let mut left = bytes;
        while left > 0 {
            let size = left.min(pkt);
            self.events.push(at + off, Event::BgInject { port: dst, size });
            // 1 ns apart: a fixed arrival order without artificial ties
            off += 1;
            left -= size;
        }
    }

    // ---- background traffic ----------------------------------------------------

    fn bg_arrival(&mut self) {
        let Some(bg) = &mut self.bg else { return };
        let flow = bg.next_flow(self.time);
        let pkts = bg.packetize(&flow);
        let next = bg.next_arrival_ns;
        for (off, size) in pkts {
            self.events.push(
                self.time + off,
                Event::BgInject {
                    port: flow.port,
                    size,
                },
            );
        }
        self.events.push(next, Event::BgArrival);
    }

    fn bg_inject(&mut self, port: NodeId, size: usize) {
        // Background packets occupy queue space and port bandwidth but are
        // sunk at the host NIC (they belong to other tenants; they land
        // directly on the destination's edge port — the incast locus —
        // in every topology). Under PFC (lossless class), tenants headed
        // to a paused port stop injecting too — otherwise the fabric
        // deadlocks with that queue pinned above XOFF forever. Per-port:
        // an unrelated paused port no longer silences this tenant.
        if self.pfc_required && self.fabric.ports[port].pfc_asserted {
            return;
        }
        // Background tenants run their own congestion control (DCQCN et
        // al.): once the port queue is deep they back off rather than
        // blasting open-loop into a full buffer.
        if self.fabric.queue_bytes(port) > self.cfg.fabric.queue_cap_bytes / 2 {
            return;
        }
        let pkt = Packet {
            src: port,
            dst: port,
            size: size + crate::net::WIRE_HDR_BYTES,
            ecn: false,
            spray: false,
            kind: PktKind::Bg,
        };
        let was_idle = !self.fabric.ports[port].busy;
        match self.fabric.enqueue(port, pkt, &mut self.rng) {
            EnqueueOutcome::Dropped => {}
            EnqueueOutcome::Queued { .. } => {
                if was_idle {
                    self.port_start_tx(port);
                }
            }
        }
        self.maybe_pfc_update(port);
    }

    // ---- dispatch plumbing -------------------------------------------------------

    fn with_transport<R>(
        &mut self,
        node: NodeId,
        f: impl FnOnce(&mut dyn Transport, &mut NicCtx) -> R,
    ) -> R {
        let mut t = self.transports[node].take().expect("transport reentrancy");
        let mut ctx = NicCtx {
            time: self.time,
            node,
            mem: &mut self.mem,
            cq: &mut self.cqs[node],
            metrics: &mut self.metrics,
            rng: &mut self.rng,
            events: &mut self.events,
            nic: &mut self.nics[node],
            srq: &mut self.srqs[node],
            timers: &mut self.timers[node],
            timer_gen: &mut self.timer_gen,
        };
        let r = f(t.as_mut(), &mut ctx);
        self.transports[node] = Some(t);
        r
    }

    fn with_app<R>(
        &mut self,
        node: NodeId,
        f: impl FnOnce(&mut dyn App, &mut AppCtx) -> R,
    ) -> Option<R> {
        let mut a = self.apps[node].take()?;
        let mut t = self.transports[node].take().expect("transport reentrancy");
        let r = {
            let mut ctx = AppCtx {
                time: self.time,
                node,
                mem: &mut self.mem,
                metrics: &mut self.metrics,
                rng: &mut self.rng,
                events: &mut self.events,
                nic: &mut self.nics[node],
                transport: t.as_mut(),
                cq: &mut self.cqs[node],
                srq: &mut self.srqs[node],
                timers: &mut self.timers[node],
                timer_gen: &mut self.timer_gen,
                base_rtt_ns: self.cfg.fabric.base_rtt_ns(),
            };
            f(a.as_mut(), &mut ctx)
        };
        self.transports[node] = Some(t);
        self.apps[node] = Some(a);
        self.apps_dirty = true;
        Some(r)
    }

    /// Deliver pending completion events to the node's app via the
    /// non-allocating `poll_into` path (one scratch vector reused across
    /// every poll of the run). Loops because app reactions can
    /// synchronously produce more completions.
    fn drain_cqes(&mut self, node: NodeId) {
        for _ in 0..64 {
            if self.cqs[node].is_empty() {
                return;
            }
            let mut scratch = std::mem::take(&mut self.cq_scratch);
            scratch.clear();
            self.cqs[node].poll_into(&mut scratch);
            for ev in scratch.drain(..) {
                self.with_app(node, |a, ctx| a.on_cq_event(ctx, ev));
            }
            self.cq_scratch = scratch;
        }
        panic!("CQE drain livelock on node {node}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Engine-level smoke test with a null app; transports are exercised in
    /// `transport::*` and `rust/tests/`.
    struct NullApp {
        done: bool,
    }

    impl App for NullApp {
        fn on_start(&mut self, ctx: &mut AppCtx) {
            // wake once and finish
            ctx.wake_in(100, 1);
        }
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, _ev: CqEvent) {}
        fn on_wake(&mut self, _ctx: &mut AppCtx, token: u64) {
            assert_eq!(token, 1);
            self.done = true;
        }
        fn on_ctrl(&mut self, _ctx: &mut AppCtx, _from: NodeId, _msg: CtrlMsg) {}
        fn is_done(&self) -> bool {
            self.done
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    #[test]
    fn run_completes_null_apps() {
        let cfg = ClusterCfg::new(FabricCfg::cloudlab(2), TransportKind::Optinic);
        let mut c = Cluster::new(cfg);
        c.set_app(0, Box::new(NullApp { done: false }));
        c.set_app(1, Box::new(NullApp { done: false }));
        c.start_apps();
        assert!(c.run());
        assert_eq!(c.time, 100);
    }

    struct CtrlPing {
        peer: NodeId,
        got: bool,
        initiator: bool,
    }

    impl App for CtrlPing {
        fn on_start(&mut self, ctx: &mut AppCtx) {
            if self.initiator {
                ctx.send_ctrl(
                    self.peer,
                    CtrlMsg {
                        tag: 42,
                        payload: vec![1, 2, 3],
                    },
                );
            }
        }
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, _ev: CqEvent) {}
        fn on_wake(&mut self, _ctx: &mut AppCtx, _token: u64) {}
        fn on_ctrl(&mut self, ctx: &mut AppCtx, from: NodeId, msg: CtrlMsg) {
            assert_eq!(msg.tag, 42);
            assert_eq!(msg.payload, vec![1, 2, 3]);
            if !self.got {
                self.got = true;
                // echo back
                ctx.send_ctrl(from, msg);
            }
        }
        fn is_done(&self) -> bool {
            self.got
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    #[test]
    fn ctrl_channel_roundtrip() {
        let cfg = ClusterCfg::new(FabricCfg::cloudlab(2), TransportKind::Optinic);
        let mut c = Cluster::new(cfg);
        c.set_app(
            0,
            Box::new(CtrlPing {
                peer: 1,
                got: false,
                initiator: true,
            }),
        );
        c.set_app(
            1,
            Box::new(CtrlPing {
                peer: 0,
                got: false,
                initiator: false,
            }),
        );
        c.start_apps();
        assert!(c.run());
        assert!(c.time > 0);
    }

    #[test]
    fn connect_assigns_distinct_qpns_and_peers() {
        let cfg = ClusterCfg::new(FabricCfg::cloudlab(4), TransportKind::Optinic);
        let mut c = Cluster::new(cfg);
        let (a1, b1) = c.connect(0, 1, QpType::Xp);
        let (a2, b2) = c.connect(2, 3, QpType::Xp);
        assert_eq!(a1.peer, 1);
        assert_eq!(b1.peer, 0);
        assert_eq!(a2.peer, 3);
        assert_eq!(b2.peer, 2);
        let all = [a1.qpn, b1.qpn, a2.qpn, b2.qpn];
        for i in 0..4 {
            for j in i + 1..4 {
                assert_ne!(all[i], all[j]);
            }
        }
    }

    /// Two senders on distinct QPs, a receiver that posts NO per-QP recv
    /// WQEs — only SRQ entries. Both messages must complete as `RecvDone`
    /// events with complete loss maps, consuming exactly two SRQ entries.
    struct SrqSender {
        qp: QpHandle,
        mr: crate::verbs::MrId,
        fill: f32,
        done: bool,
    }

    impl App for SrqSender {
        fn on_start(&mut self, ctx: &mut AppCtx) {
            ctx.mem.write_f32(self.mr, 0, &vec![self.fill; 1024]);
            let wqe = Wqe::send(1, self.mr, 0, 4096).with_timeout(50_000_000);
            ctx.endpoint().post_send(self.qp, wqe);
        }
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
            if let CqEvent::SendDone { .. } | CqEvent::TimeoutFired { is_recv: false, .. } = ev
            {
                self.done = true;
            }
        }
        fn on_wake(&mut self, _ctx: &mut AppCtx, _t: u64) {}
        fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: CtrlMsg) {}
        fn is_done(&self) -> bool {
            self.done
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    struct SrqReceiver {
        mr: crate::verbs::MrId,
        got: usize,
        complete_maps: usize,
    }

    impl App for SrqReceiver {
        fn on_start(&mut self, ctx: &mut AppCtx) {
            // two shared entries, no per-QP recv WQEs at all
            let slots = vec![
                Wqe::recv(10, self.mr, 0, 4096).with_timeout(50_000_000),
                Wqe::recv(11, self.mr, 4096, 4096).with_timeout(50_000_000),
            ];
            let mut ep = ctx.endpoint();
            ep.post_srq_recv_batch(slots);
            assert_eq!(ep.srq_len(), 2);
        }
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
            if let CqEvent::RecvDone { loss_map, .. } = ev {
                self.got += 1;
                if loss_map.is_complete() {
                    self.complete_maps += 1;
                }
            }
        }
        fn on_wake(&mut self, _ctx: &mut AppCtx, _t: u64) {}
        fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: CtrlMsg) {}
        fn is_done(&self) -> bool {
            self.got >= 2
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    fn run_srq_feeds(transport: TransportKind) {
        let mut fab = FabricCfg::cloudlab(3);
        fab.corrupt_prob = 0.0; // lossless: loss maps must come back complete
        let cfg = ClusterCfg::new(fab, transport).with_seed(9);
        let mut c = Cluster::new(cfg);
        let dst = c.mem.register(0, 8192);
        let src1 = c.mem.register(1, 4096);
        let src2 = c.mem.register(2, 4096);
        let (s1, _r1) = c.connect(1, 0, QpType::Xp);
        let (s2, _r2) = c.connect(2, 0, QpType::Xp);
        c.set_app(
            0,
            Box::new(SrqReceiver {
                mr: dst,
                got: 0,
                complete_maps: 0,
            }),
        );
        c.set_app(
            1,
            Box::new(SrqSender {
                qp: s1,
                mr: src1,
                fill: 7.5,
                done: false,
            }),
        );
        c.set_app(
            2,
            Box::new(SrqSender {
                qp: s2,
                mr: src2,
                fill: 8.5,
                done: false,
            }),
        );
        c.start_apps();
        assert!(c.run(), "{transport:?}: SRQ run did not complete");
        assert_eq!(c.srq_consumed(0), 2, "{transport:?}: SRQ entries consumed");
        // both 4 KB messages landed (one per slot, arrival order unspecified)
        let data = c.mem.read_f32(dst, 0, 2048);
        let sevens = data.iter().filter(|&&v| v == 7.5).count();
        let eights = data.iter().filter(|&&v| v == 8.5).count();
        assert_eq!(sevens, 1024, "{transport:?}: sender-1 payload placed");
        assert_eq!(eights, 1024, "{transport:?}: sender-2 payload placed");
        let mut app = c.take_app(0).unwrap();
        let recv = app.as_any().downcast_mut::<SrqReceiver>().unwrap();
        assert_eq!(recv.complete_maps, 2, "{transport:?}: loss maps complete");
    }

    #[test]
    fn srq_feeds_multiple_qps_optinic() {
        run_srq_feeds(TransportKind::Optinic);
    }

    #[test]
    fn srq_feeds_multiple_qps_reliable() {
        run_srq_feeds(TransportKind::Irn);
    }

    /// Satellite regression (fails pre-fix): PFC was one global switch —
    /// any port above XOFF paused EVERY host's data class, so a hot port
    /// nobody talks to froze unrelated flows. Here port 1 is pinned above
    /// XOFF for the whole run (its drain is never scheduled) while an
    /// unrelated 2 → 3 transfer runs; per-port PFC lets it complete,
    /// global PFC blocked node 2's data class forever.
    #[test]
    fn pfc_idle_port_not_paused_by_unrelated_hot_port() {
        use crate::net::{DataHdr, NetHints};
        use crate::verbs::MrId;
        let mut fab = FabricCfg::cloudlab(4);
        fab.corrupt_prob = 0.0;
        let mut c = Cluster::new(ClusterCfg::new(fab, TransportKind::Roce).with_seed(3));
        // pin port 1 above XOFF: fill it directly, never kick its drain
        let mut rng = crate::util::prng::Pcg64::seeded(99);
        let hot = |len: usize| {
            Packet::data(
                0,
                1,
                DataHdr {
                    dst_qpn: 0,
                    src_qpn: 0,
                    psn: 0,
                    wqe_seq: 0,
                    msg_offset: 0,
                    len,
                    last: false,
                    msg_len: len,
                    src_mr: MrId(0),
                    src_off: 0,
                    reth: None,
                    stride: 1,
                    imm: None,
                    deadline: None,
                    tx_time: 0,
                    hints: NetHints::default(),
                },
            )
        };
        while c.fabric.queue_bytes(1) < c.cfg.fabric.pfc_xoff {
            assert!(matches!(
                c.fabric.enqueue(1, hot(4096), &mut rng),
                EnqueueOutcome::Queued { .. }
            ));
        }
        c.events.push(0, Event::PfcUpdate { link: 1 });
        // unrelated flow: 64 KB from node 2 to node 3 (idle port) — big
        // enough that the pause frames land mid-message
        let dst = c.mem.register(3, 64 * 1024);
        let src = c.mem.register(2, 64 * 1024);
        let (s, _r) = c.connect(2, 3, QpType::Xp);
        struct OneShotSender {
            qp: QpHandle,
            mr: crate::verbs::MrId,
            done: bool,
        }
        impl App for OneShotSender {
            fn on_start(&mut self, ctx: &mut AppCtx) {
                ctx.endpoint()
                    .post_send(self.qp, Wqe::send(1, self.mr, 0, 64 * 1024));
            }
            fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
                if matches!(ev, CqEvent::SendDone { .. }) {
                    self.done = true;
                }
            }
            fn on_wake(&mut self, _c: &mut AppCtx, _t: u64) {}
            fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: CtrlMsg) {}
            fn is_done(&self) -> bool {
                self.done
            }
            fn as_any(&mut self) -> &mut dyn std::any::Any {
                self
            }
        }
        struct OneShotReceiver {
            mr: crate::verbs::MrId,
            got: bool,
        }
        impl App for OneShotReceiver {
            fn on_start(&mut self, ctx: &mut AppCtx) {
                ctx.endpoint()
                    .post_srq_recv(Wqe::recv(10, self.mr, 0, 64 * 1024));
            }
            fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
                if matches!(ev, CqEvent::RecvDone { .. }) {
                    self.got = true;
                }
            }
            fn on_wake(&mut self, _c: &mut AppCtx, _t: u64) {}
            fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: CtrlMsg) {}
            fn is_done(&self) -> bool {
                self.got
            }
            fn as_any(&mut self) -> &mut dyn std::any::Any {
                self
            }
        }
        c.set_app(
            2,
            Box::new(OneShotSender {
                qp: s,
                mr: src,
                done: false,
            }),
        );
        c.set_app(3, Box::new(OneShotReceiver { mr: dst, got: false }));
        c.cfg.max_sim_time = 100 * crate::sim::MS;
        c.start_apps();
        assert!(
            c.run(),
            "idle-port flow must complete while an unrelated port is paused"
        );
        // the pause really happened — for port 1, at every host
        assert!(c.fabric.ports[1].pfc_asserted, "hot port must stay asserted");
        assert!(c.metrics.pfc_pause_events >= 4, "pause frames delivered");
    }

    /// Leaf–spine smoke: the SRQ contract holds across the multi-tier
    /// fabric (cross-leaf placement, both engine families).
    #[test]
    fn srq_feeds_over_leaf_spine() {
        for transport in [TransportKind::Optinic, TransportKind::Irn] {
            let mut fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
            fab.corrupt_prob = 0.0;
            let cfg = ClusterCfg::new(fab, transport).with_seed(9);
            let mut c = Cluster::new(cfg);
            let dst = c.mem.register(0, 8192);
            let src1 = c.mem.register(2, 4096); // cross-leaf sender
            let src2 = c.mem.register(3, 4096); // cross-leaf sender
            let (s1, _r1) = c.connect(2, 0, QpType::Xp);
            let (s2, _r2) = c.connect(3, 0, QpType::Xp);
            c.set_app(
                0,
                Box::new(SrqReceiver {
                    mr: dst,
                    got: 0,
                    complete_maps: 0,
                }),
            );
            c.set_app(
                2,
                Box::new(SrqSender {
                    qp: s1,
                    mr: src1,
                    fill: 7.5,
                    done: false,
                }),
            );
            c.set_app(
                3,
                Box::new(SrqSender {
                    qp: s2,
                    mr: src2,
                    fill: 8.5,
                    done: false,
                }),
            );
            c.start_apps();
            assert!(c.run(), "{transport:?}: leaf–spine SRQ run did not complete");
            let data = c.mem.read_f32(dst, 0, 2048);
            assert_eq!(data.iter().filter(|&&v| v == 7.5).count(), 1024);
            assert_eq!(data.iter().filter(|&&v| v == 8.5).count(), 1024);
            // traffic really crossed the core: spine ports forwarded bytes
            let core_tx: u64 = (c.nodes()..c.fabric.topo.n_links())
                .map(|l| c.fabric.ports[l].tx_bytes)
                .sum();
            assert!(core_tx > 0, "{transport:?}: no core-link traffic");
        }
    }

    /// Wholly-lost messages must not strand an SRQ-only receiver: entries
    /// whose queue-level deadline expires before any fragment arrives
    /// complete as `TimeoutFired` (here: no sender exists at all).
    struct SrqTimeoutApp {
        mr: crate::verbs::MrId,
        timeouts: usize,
        want: usize,
    }

    impl App for SrqTimeoutApp {
        fn on_start(&mut self, ctx: &mut AppCtx) {
            let slots: Vec<Wqe> = (0..self.want)
                .map(|i| {
                    Wqe::recv(i as u64, self.mr, i * 1024, 1024)
                        .with_timeout(1_000_000 * (i as u64 + 1))
                })
                .collect();
            ctx.endpoint().post_srq_recv_batch(slots);
        }
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
            if let CqEvent::TimeoutFired {
                is_recv: true,
                delivered_bytes: 0,
                expected_bytes: 1024,
                ..
            } = ev
            {
                self.timeouts += 1;
            }
        }
        fn on_wake(&mut self, _ctx: &mut AppCtx, _t: u64) {}
        fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: CtrlMsg) {}
        fn is_done(&self) -> bool {
            self.timeouts >= self.want
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    #[test]
    fn srq_entries_time_out_when_wholly_lost() {
        let cfg = ClusterCfg::new(FabricCfg::cloudlab(2), TransportKind::Optinic);
        let mut c = Cluster::new(cfg);
        let mr = c.mem.register(0, 2048);
        c.set_app(
            0,
            Box::new(SrqTimeoutApp {
                mr,
                timeouts: 0,
                want: 2,
            }),
        );
        c.start_apps();
        assert!(c.run(), "SRQ-only receiver must not hang on total loss");
        assert_eq!(c.time, 2_000_000, "second entry's deadline gates completion");
        assert_eq!(c.srq_consumed(0), 0, "nothing ever consumed the entries");
    }

    /// Wheel and heap backends must drive the engine through bit-identical
    /// trajectories (the full-stack parity suite lives in
    /// `rust/tests/determinism.rs`).
    #[test]
    fn scheduler_parity_smoke() {
        let run = |sched: SchedKind| {
            let cfg = ClusterCfg::new(FabricCfg::cloudlab(4), TransportKind::Optinic)
                .with_seed(7)
                .with_bg_load(0.4)
                .with_scheduler(sched);
            let mut c = Cluster::new(cfg);
            c.set_app(0, Box::new(NullApp { done: false }));
            c.cfg.max_sim_time = 500_000;
            c.start_apps();
            c.run();
            c.run_until(400_000);
            (
                c.time,
                c.events_processed,
                c.metrics.pkts_dropped_queue,
                c.metrics.tx_trains,
                c.metrics.tx_train_pkts,
            )
        };
        assert_eq!(run(SchedKind::Wheel), run(SchedKind::Heap));
    }

    /// Same parity contract over the multi-tier fabric: per-hop queues,
    /// ECMP, spraying, and bg traffic must be scheduler-invariant too.
    #[test]
    fn scheduler_parity_smoke_leaf_spine() {
        let run = |sched: SchedKind| {
            let fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
            let cfg = ClusterCfg::new(fab, TransportKind::Optinic)
                .with_seed(7)
                .with_bg_load(0.4)
                .with_scheduler(sched);
            let mut c = Cluster::new(cfg);
            c.set_app(0, Box::new(NullApp { done: false }));
            c.cfg.max_sim_time = 500_000;
            c.start_apps();
            c.run();
            c.run_until(400_000);
            (
                c.time,
                c.events_processed,
                c.metrics.pkts_dropped_queue,
                c.metrics.tx_trains,
                c.metrics.tx_train_pkts,
            )
        };
        assert_eq!(run(SchedKind::Wheel), run(SchedKind::Heap));
    }

    #[test]
    fn deterministic_event_counts() {
        let run = |seed| {
            let cfg = ClusterCfg::new(FabricCfg::cloudlab(4), TransportKind::Optinic)
                .with_seed(seed)
                .with_bg_load(0.3);
            let mut c = Cluster::new(cfg);
            c.set_app(0, Box::new(NullApp { done: false }));
            // run some bg traffic alongside
            c.cfg.max_sim_time = 200_000;
            c.start_apps();
            c.run();
            (c.events_processed, c.metrics.pkts_dropped_queue)
        };
        assert_eq!(run(7), run(7));
    }
}
