//! The cluster engine: hosts + NICs + fabric + transports + applications,
//! driven by one deterministic event loop.
//!
//! Ownership pattern: `Cluster` owns every component; event handlers take
//! the per-node transport/app out of its slot (`Option::take`), build a
//! context borrowing the *rest* of the cluster, dispatch, and put it back.
//! This gives components mutable access to shared state (memory pool, event
//! queue, metrics) without `Rc<RefCell>` on the hot path.
//!
//! Verbs v2 surface: applications receive typed [`CqEvent`]s through
//! [`App::on_cq_event`] and post work through [`Endpoint`] (obtained from
//! [`AppCtx::endpoint`]) using [`QpHandle`]s — single posts, doorbell-batched
//! posts, and shared-receive-queue posts. The engine drains completions with
//! the non-allocating `CompletionQueue::poll_into` into one reusable scratch
//! vector.

use crate::net::{
    BgTraffic, CtrlMsg, EnqueueOutcome, Fabric, FabricCfg, LinkDst, LinkId, NetFault,
    Packet, PartitionMap, PktKind, SwitchCode,
};
use crate::sim::sched::EventKey;
use crate::sim::{EventQueue, Metrics, SchedKind, SimTime};
use crate::transport::{Transport, TransportCfg, TransportKind};
use crate::util::prng::Pcg64;
use crate::verbs::{
    CompletionQueue, CqEvent, Cqe, MemPool, MrId, NodeId, Qp, QpHandle, QpType, Qpn, Srq,
    Wqe,
};

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};

/// Default cap on packets coalesced into one egress serialization train
/// (`ClusterCfg::train_max`). Bounds both the per-event burst work and the
/// window in which a mid-train PFC pause cannot interrupt committed
/// packets (real NICs have the same in-flight burst exposure).
pub const TRAIN_MAX_DEFAULT: usize = 8;

/// One packet of a coalesced serialization train, with its finish time
/// reconstructed arithmetically at scheduling (start + cumulative
/// serialization delays).
#[derive(Debug)]
pub struct TrainPkt {
    pub pkt: Packet,
    pub done_at: SimTime,
}

/// Engine events.
#[derive(Debug)]
pub enum Event {
    /// Try to start serializing the next packet from a host NIC.
    HostTxKick(NodeId),
    /// Host NIC finished serializing `Packet` onto its uplink.
    HostTxDone(NodeId, Packet),
    /// Packet reached switch `sw`'s ingress (topology switch code: the
    /// single ToR is `0`; leaf–spine leaves come first, then spines).
    SwitchArrive { sw: SwitchCode, pkt: Packet },
    /// Egress link finished serializing `Packet`.
    PortTxDone(LinkId, Packet),
    /// First packet of a coalesced serialization train finished (host
    /// uplink when `port` is false — `idx` is the node — or a switch
    /// egress link when true — `idx` is the link). The remaining packets'
    /// finish times ride in the train, all `>=` this event's time — one
    /// scheduler round-trip per burst instead of one
    /// `HostTxDone`/`PortTxDone` per packet (§Perf).
    TxTrainDone {
        idx: usize,
        port: bool,
        train: Vec<TrainPkt>,
    },
    /// The link that carried a train frees at the LAST packet's finish
    /// time: clear busy and restart egress.
    TxTrainFree { idx: usize, port: bool },
    /// Packet delivered to a host NIC.
    HostRx(Packet),
    /// Transport-managed timer, stamped with the arming generation so
    /// re-armed/cancelled logical timers are dropped at fire time without
    /// dispatching into the transport (lazy cancellation).
    TransportTimer {
        node: NodeId,
        timer_id: u64,
        gen: u64,
    },
    /// Application wake-up (collective timeouts, compute completion, ...).
    AppWake { node: NodeId, token: u64 },
    /// Background-traffic flow arrival.
    BgArrival,
    /// One background packet hits a switch port queue.
    BgInject { port: NodeId, size: usize },
    /// Re-evaluate one edge port's PFC state (per-port pause/resume).
    PfcUpdate { link: LinkId },
    /// Queue-level deadline for a shared-receive-queue entry (verbs v2):
    /// if the entry is still waiting when this fires, it completes as
    /// `TimeoutFired` so an SRQ-only receiver can never be stranded by a
    /// wholly-lost message.
    SrqDeadline { node: NodeId, entry_id: u64 },
    /// SEU fault injection: corrupt random NIC state on `node`
    /// (behavioral fault-tolerance experiment, §2.4). The victim is drawn
    /// at SCHEDULING time so the fault campaign is part of the
    /// deterministic event schedule — the partitioned engine routes the
    /// event to the node's partition like any other per-node event.
    InjectFault { node: NodeId },
    /// Link-level fault action: flap, degrade, routing convergence
    /// (scenario builders live in `hw::fault`).
    NetFault(NetFault),
}

// ---- hot-path footprint guards (§Perf) -------------------------------------
// `Event` is pushed/popped for every simulated packet hop; its size is
// `Packet` (whose fattest variant is `Data(DataHdr)`) plus a word or two
// of variant framing. A regression here taxes every scheduler operation,
// so it fails the build loudly rather than showing up as a slow sweep.
const _: () = assert!(
    std::mem::size_of::<Event>() <= std::mem::size_of::<crate::net::Packet>() + 24
);
const _: () = assert!(std::mem::size_of::<Event>() <= 208);
const _: () = assert!(
    std::mem::size_of::<TrainPkt>() <= std::mem::size_of::<crate::net::Packet>() + 8
);

// ---- partitioned engine plumbing -------------------------------------------

/// Freelist caps: empty train buffers / ctrl boxes held for reuse per
/// shard. Small and bounded — the pools exist to stop per-event heap
/// churn on the hot path, not to cache a working set.
const TRAIN_POOL_MAX: usize = 64;
const CTRL_POOL_MAX: usize = 64;

/// A cross-partition event in flight between conservative windows.
/// Stamped with `(time, origin, seq)` so every receiver inserts envelopes
/// in an order independent of worker count, and optionally carrying a
/// payload refresh for data fragments (the receiving shard's memory
/// replica must see the sender's bytes before its transport places them).
#[derive(Debug)]
pub struct Envelope {
    time: SimTime,
    origin: u32,
    seq: u64,
    ev: Event,
    refresh: Option<Refresh>,
}

#[derive(Debug)]
enum Refresh {
    /// Recorded at push time: (region, offset, len) of the fragment's DMA
    /// source span in the sending shard's replica.
    Span(MrId, usize, usize),
    /// Sealed at window flush with the replica's bytes.
    Bytes(MrId, usize, Box<[u8]>),
}

/// Routing state a shard's event sink carries: which partition it is,
/// the topology cut, its per-origin key counter, and one outbox per
/// destination partition for events that leave the shard.
#[derive(Debug)]
struct RouteState {
    part: u32,
    pmap: Arc<PartitionMap>,
    /// Per-origin insertion counter. Every push — local or remote —
    /// consumes one tick, so the key sequence a handler produces is a
    /// pure function of the event order, not of where events land.
    seq: u64,
    /// End of the window currently executing (cross-partition pushes must
    /// land at or beyond it — the conservative lookahead guarantee).
    window_end: SimTime,
    outbox: Vec<Vec<Envelope>>,
}

/// The engine's event queue, optionally partition-aware. The legacy
/// single-threaded engine uses it as a plain [`EventQueue`] (`route` is
/// `None`, `push` keeps the classic FIFO tie-break). A partitioned shard
/// routes every push by the event's owning partition: local events enter
/// the queue keyed `(part, seq)`, foreign events go to the owner's
/// outbox as [`Envelope`]s delivered at the next window boundary.
#[derive(Debug)]
pub struct EventSink {
    q: EventQueue<Event>,
    route: Option<RouteState>,
}

impl EventSink {
    fn single(kind: SchedKind) -> EventSink {
        EventSink {
            q: EventQueue::with_kind(kind),
            route: None,
        }
    }

    fn sharded(
        kind: SchedKind,
        part: u32,
        pmap: Arc<PartitionMap>,
        seq0: u64,
    ) -> EventSink {
        let n = pmap.n_parts;
        EventSink {
            q: EventQueue::with_kind(kind),
            route: Some(RouteState {
                part,
                pmap,
                seq: seq0,
                window_end: 0,
                outbox: (0..n).map(|_| Vec::new()).collect(),
            }),
        }
    }

    /// Schedule an event. Single-queue mode keeps the legacy FIFO
    /// tie-break; a partitioned shard keys it `(part, seq)` and routes it
    /// to its owning partition.
    pub fn push(&mut self, time: SimTime, ev: Event) {
        let Some(r) = &mut self.route else {
            self.q.push(time, ev);
            return;
        };
        r.seq += 1;
        let owner = ev_owner(&r.pmap, r.part, &ev);
        if owner == r.part {
            self.q.push_keyed(time, (r.part, r.seq), ev);
        } else {
            // conservative lookahead: anything that leaves the partition
            // rides >= one propagation delay, so it can never land inside
            // the window that produced it
            debug_assert!(
                time >= r.window_end,
                "cross-partition event inside its own window"
            );
            let refresh = refresh_span(&ev);
            r.outbox[owner as usize].push(Envelope {
                time,
                origin: r.part,
                seq: r.seq,
                ev,
                refresh,
            });
        }
    }

    /// Insert with an explicit pre-assigned key (setup events distributed
    /// at the shard split, envelopes delivered at a window boundary).
    fn push_prekeyed(&mut self, time: SimTime, key: EventKey, ev: Event) {
        self.q.push_keyed(time, key, ev);
    }

    pub fn pop(&mut self) -> Option<(SimTime, Event)> {
        self.q.pop()
    }

    pub fn peek_time(&self) -> Option<SimTime> {
        self.q.peek_time()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn clear(&mut self) {
        self.q.clear()
    }
}

/// The partition that must execute an event. Per-node events follow the
/// node, per-link events the link's source switch, per-switch events the
/// switch; `BgArrival` is each shard's private arrival clock.
fn ev_owner(pmap: &PartitionMap, own: u32, ev: &Event) -> u32 {
    match ev {
        Event::HostTxKick(n) | Event::HostTxDone(n, _) => pmap.node_part[*n],
        Event::SwitchArrive { sw, .. } => pmap.switch_part[*sw as usize],
        Event::PortTxDone(l, _) => pmap.link_part[*l],
        Event::TxTrainDone { idx, port, .. } | Event::TxTrainFree { idx, port } => {
            if *port {
                pmap.link_part[*idx]
            } else {
                pmap.node_part[*idx]
            }
        }
        Event::HostRx(pkt) => pmap.node_part[pkt.dst],
        Event::TransportTimer { node, .. }
        | Event::AppWake { node, .. }
        | Event::SrqDeadline { node, .. }
        | Event::InjectFault { node } => pmap.node_part[*node],
        Event::BgArrival => own,
        Event::BgInject { port, .. } => pmap.link_part[*port],
        Event::PfcUpdate { link } => pmap.link_part[*link],
        Event::NetFault(f) => match f {
            NetFault::LinkDown(l)
            | NetFault::LinkUp(l)
            | NetFault::RerouteOut(l)
            | NetFault::Degrade(l, _) => pmap.link_part[*l],
        },
    }
}

/// DMA source span of a cross-partition data fragment, if any: the bytes
/// the receiving shard's replica must refresh before its transport runs
/// the placement copy.
fn refresh_span(ev: &Event) -> Option<Refresh> {
    let pkt = match ev {
        Event::SwitchArrive { pkt, .. } => pkt,
        Event::HostRx(pkt) => pkt,
        _ => return None,
    };
    match &pkt.kind {
        PktKind::Data(h) if h.len > 0 => Some(Refresh::Span(h.src_mr, h.src_off, h.len)),
        _ => None,
    }
}

/// Per-node NIC front: egress queues ahead of the uplink.
#[derive(Debug, Default)]
pub struct Nic {
    /// Data-class egress (subject to PFC pause).
    pub data_q: VecDeque<Packet>,
    /// Control-class egress (ACK/CNP/credit/ctrl — never paused; this is
    /// how real deployments avoid PFC deadlocks on the ACK class).
    pub ctrl_q: VecDeque<Packet>,
    pub tx_busy: bool,
    /// Per-destination PFC pause state, indexed by destination host:
    /// set/cleared by that destination's edge port crossing XOFF/XON.
    /// (Pre-fix this was a single bool — one hot port paused every
    /// sender's entire data class.)
    pub paused_dsts: Vec<bool>,
    paused_since: Vec<SimTime>,
}

impl Nic {
    fn new(nodes: usize) -> Nic {
        Nic {
            paused_dsts: vec![false; nodes],
            paused_since: vec![0; nodes],
            ..Nic::default()
        }
    }

    /// Next packet eligible for the uplink: control class first (it
    /// bypasses PFC pause), then data. The data FIFO blocks on a paused
    /// HEAD — head-of-line within the sender queue is the realistic PFC
    /// cost — but an unpaused head flows even while other destinations
    /// are paused.
    fn pop_egress(&mut self) -> Option<Packet> {
        if let Some(p) = self.ctrl_q.pop_front() {
            return Some(p);
        }
        match self.data_q.front() {
            Some(p) if !self.paused_dsts[p.dst] => self.data_q.pop_front(),
            _ => None,
        }
    }

    /// Would `pop_egress` currently yield a packet?
    fn has_egress(&self) -> bool {
        !self.ctrl_q.is_empty()
            || self.data_q.front().is_some_and(|p| !self.paused_dsts[p.dst])
    }
}

/// Context handed to transports.
pub struct NicCtx<'a> {
    pub time: SimTime,
    pub node: NodeId,
    pub mem: &'a mut MemPool,
    pub cq: &'a mut CompletionQueue,
    pub metrics: &'a mut Metrics,
    pub rng: &'a mut Pcg64,
    events: &'a mut EventSink,
    nic: &'a mut Nic,
    srq: &'a mut Srq,
    /// This node's armed transport timers: timer_id → live generation.
    timers: &'a mut HashMap<u64, u64>,
    /// Cluster-wide generation source (globally unique, so a consumed id
    /// can be re-armed without aliasing an old in-flight entry).
    timer_gen: &'a mut u64,
}

impl<'a> NicCtx<'a> {
    /// Queue a packet for transmission on this NIC's uplink.
    pub fn tx(&mut self, pkt: Packet) {
        debug_assert_eq!(pkt.src, self.node);
        let is_ctrl = !pkt.is_data();
        if let PktKind::Data(h) = &pkt.kind {
            self.metrics.data_bytes_sent += h.len as u64;
        }
        self.metrics.pkts_sent += 1;
        if is_ctrl {
            self.nic.ctrl_q.push_back(pkt);
        } else {
            self.nic.data_q.push_back(pkt);
        }
        // §Perf: kick only an idle NIC — a busy NIC re-kicks itself from
        // HostTxDone, so unconditional per-packet kicks just churn the
        // event heap (measurable on multi-MB collectives).
        if !self.nic.tx_busy {
            self.events.push(self.time, Event::HostTxKick(self.node));
        }
    }

    /// Arm — or re-arm — transport timer `timer_id` to fire after
    /// `delay`. Re-arming replaces the previous deadline: the superseded
    /// queue entry stays where it is and is dropped at fire time by its
    /// stale generation stamp (lazy cancellation), so re-arms are O(1)
    /// and stale fires never reach the transport.
    pub fn set_timer(&mut self, delay: SimTime, timer_id: u64) {
        *self.timer_gen += 1;
        let gen = *self.timer_gen;
        self.timers.insert(timer_id, gen);
        self.events.push(
            self.time + delay,
            Event::TransportTimer {
                node: self.node,
                timer_id,
                gen,
            },
        );
    }

    /// Disarm `timer_id`. Lazy: the scheduled entry is dropped when it
    /// fires. No-op if the timer is not armed.
    pub fn cancel_timer(&mut self, timer_id: u64) {
        self.timers.remove(&timer_id);
    }

    /// Push an internal wire CQE; it is converted to a typed `CqEvent` at
    /// the completion-queue boundary (apps never see `Cqe`).
    pub fn push_cqe(&mut self, cqe: Cqe) {
        self.cq.push_wire(cqe);
    }

    /// Pop the next shared-receive-queue entry, if any (SRQ fallback for
    /// two-sided messages arriving on a QP with an empty receive queue).
    pub fn pop_srq(&mut self) -> Option<Wqe> {
        self.srq.pop()
    }
}

/// Context handed to applications (collective engines, drivers). Verbs
/// operations live on [`Endpoint`] (see [`AppCtx::endpoint`]); this struct
/// keeps the non-verbs utilities (memory, wake-ups, control plane).
pub struct AppCtx<'a> {
    pub time: SimTime,
    pub node: NodeId,
    pub mem: &'a mut MemPool,
    pub metrics: &'a mut Metrics,
    pub rng: &'a mut Pcg64,
    events: &'a mut EventSink,
    nic: &'a mut Nic,
    transport: &'a mut dyn Transport,
    /// Freelist of control-message boxes (recycled by the engine when a
    /// ctrl packet is consumed) — `send_ctrl` reuses the allocation.
    ctrl_pool: &'a mut Vec<Box<CtrlMsg>>,
    cq: &'a mut CompletionQueue,
    srq: &'a mut Srq,
    timers: &'a mut HashMap<u64, u64>,
    timer_gen: &'a mut u64,
    base_rtt_ns: u64,
}

impl<'a> AppCtx<'a> {
    /// The verbs v2 posting surface for this node's NIC.
    pub fn endpoint(&mut self) -> Endpoint<'_, 'a> {
        Endpoint { ctx: self }
    }

    /// Schedule an application wake-up.
    pub fn wake_in(&mut self, delay: SimTime, token: u64) {
        self.events.push(
            self.time + delay,
            Event::AppWake {
                node: self.node,
                token,
            },
        );
    }

    /// Send a reliable control-plane message (handshakes, timeout stats).
    /// Delivered after one-way base latency + negligible serialization —
    /// the paper's "pre-existing reliable channel" (§3.1.2).
    pub fn send_ctrl(&mut self, to: NodeId, msg: CtrlMsg) {
        // §Perf: reuse a recycled ctrl box instead of allocating one per
        // message (the box keeps the rare-but-open-ended payload off the
        // hot-path `Packet` union; the freelist keeps it off the heap)
        let kind = match self.ctrl_pool.pop() {
            Some(mut b) => {
                *b = msg;
                PktKind::Ctrl(b)
            }
            None => PktKind::Ctrl(Box::new(msg)),
        };
        let payload_len = match &kind {
            PktKind::Ctrl(m) => m.payload.len(),
            _ => unreachable!(),
        };
        let pkt = Packet {
            src: self.node,
            dst: to,
            size: crate::net::WIRE_HDR_BYTES + payload_len,
            ecn: false,
            spray: false,
            kind,
        };
        // reliable channel: bypasses the lossy data fabric
        self.events
            .push(self.time + self.base_rtt_ns / 2, Event::HostRx(pkt));
    }

    pub fn base_rtt_ns(&self) -> u64 {
        self.base_rtt_ns
    }
}

/// The verbs v2 posting handle: typed [`QpHandle`]s, doorbell-batched
/// posts, and the node's shared receive queue. Borrowed from an
/// [`AppCtx`] for the duration of the posting calls.
pub struct Endpoint<'c, 'a> {
    ctx: &'c mut AppCtx<'a>,
}

impl<'c, 'a> Endpoint<'c, 'a> {
    /// Post one send WQE (rings one doorbell; prefer
    /// [`Endpoint::post_send_batch`] when posting several).
    pub fn post_send(&mut self, qp: QpHandle, wqe: Wqe) {
        let (transport, mut nic_ctx) = split_ctx(self.ctx);
        transport.post_send(&mut nic_ctx, qp.qpn, wqe);
    }

    /// Post one receive WQE on a specific QP.
    pub fn post_recv(&mut self, qp: QpHandle, wqe: Wqe) {
        let (transport, mut nic_ctx) = split_ctx(self.ctx);
        transport.post_recv(&mut nic_ctx, qp.qpn, wqe);
    }

    /// Post many send WQEs with one doorbell per touched QP.
    pub fn post_send_batch(&mut self, posts: impl IntoIterator<Item = (QpHandle, Wqe)>) {
        let batch: Vec<(Qpn, Wqe)> =
            posts.into_iter().map(|(h, w)| (h.qpn, w)).collect();
        if batch.is_empty() {
            return;
        }
        let (transport, mut nic_ctx) = split_ctx(self.ctx);
        transport.post_send_batch(&mut nic_ctx, batch);
    }

    /// Post many receive WQEs in one engine crossing.
    pub fn post_recv_batch(&mut self, posts: impl IntoIterator<Item = (QpHandle, Wqe)>) {
        let batch: Vec<(Qpn, Wqe)> =
            posts.into_iter().map(|(h, w)| (h.qpn, w)).collect();
        if batch.is_empty() {
            return;
        }
        let (transport, mut nic_ctx) = split_ctx(self.ctx);
        transport.post_recv_batch(&mut nic_ctx, batch);
    }

    /// Post a receive WQE to the node's shared receive queue: any QP whose
    /// own RQ is empty consumes SRQ entries in FIFO order. If the WQE
    /// carries a timeout, a queue-level deadline is armed immediately — an
    /// entry still unconsumed when it fires completes as `TimeoutFired`
    /// (a wholly-lost message must not strand the receiver).
    pub fn post_srq_recv(&mut self, wqe: Wqe) {
        let deadline = wqe.timeout;
        let entry_id = self.ctx.srq.post(wqe);
        if let Some(t) = deadline {
            self.ctx.events.push(
                self.ctx.time + t,
                Event::SrqDeadline {
                    node: self.ctx.node,
                    entry_id,
                },
            );
        }
    }

    /// Batch-post SRQ entries.
    pub fn post_srq_recv_batch(&mut self, posts: impl IntoIterator<Item = Wqe>) {
        for wqe in posts {
            self.post_srq_recv(wqe);
        }
    }

    /// Entries currently waiting in the shared receive queue.
    pub fn srq_len(&self) -> usize {
        self.ctx.srq.len()
    }
}

/// Reborrow an `AppCtx` into the transport reference plus a `NicCtx` over
/// the remaining shared state (disjoint fields, so both can be mutable).
fn split_ctx<'c, 'a>(ctx: &'c mut AppCtx<'a>) -> (&'c mut dyn Transport, NicCtx<'c>) {
    let nic_ctx = NicCtx {
        time: ctx.time,
        node: ctx.node,
        mem: &mut *ctx.mem,
        cq: &mut *ctx.cq,
        metrics: &mut *ctx.metrics,
        rng: &mut *ctx.rng,
        events: &mut *ctx.events,
        nic: &mut *ctx.nic,
        srq: &mut *ctx.srq,
        timers: &mut *ctx.timers,
        timer_gen: &mut *ctx.timer_gen,
    };
    (&mut *ctx.transport, nic_ctx)
}

/// An application running on every node (one instance per rank).
/// `Send` because the partitioned engine moves each node's boxed app onto
/// the worker thread that owns its partition for the duration of a run.
pub trait App: Send {
    fn on_start(&mut self, ctx: &mut AppCtx);
    /// A typed, loss-aware completion event (verbs v2). Raw CQEs never
    /// reach applications.
    fn on_cq_event(&mut self, ctx: &mut AppCtx, ev: CqEvent);
    fn on_wake(&mut self, ctx: &mut AppCtx, token: u64);
    fn on_ctrl(&mut self, ctx: &mut AppCtx, from: NodeId, msg: CtrlMsg);
    fn is_done(&self) -> bool;
    /// Downcast support so drivers can extract results after a run.
    fn as_any(&mut self) -> &mut dyn std::any::Any;
}

/// Cluster configuration.
#[derive(Clone, Debug)]
pub struct ClusterCfg {
    pub fabric: FabricCfg,
    pub transport: TransportKind,
    pub transport_cfg: TransportCfg,
    pub bg_load: f64,
    pub seed: u64,
    /// Hard wall: the run aborts (returning what happened so far) if the
    /// clock passes this. Guards against protocol deadlocks in experiments.
    pub max_sim_time: SimTime,
    /// Event scheduler backend. The timing wheel is the default; the
    /// reference heap stays selectable for A/B parity testing (both yield
    /// bit-identical event order — see `rust/tests/determinism.rs`).
    pub scheduler: SchedKind,
    /// Max packets coalesced into one egress serialization train (host
    /// uplink and switch downlink). `1` restores one serialization event
    /// per packet (the pre-train engine behavior, kept for comparison).
    pub train_max: usize,
    /// Per-rank compute-delay injection (straggler choreography): rank
    /// `r`'s workload start is postponed by `compute_delays[r]` ns on top
    /// of any spec-level start delay. Empty = no stragglers. The scenario
    /// subsystem drives this so a straggler rides along with ANY workload
    /// run on the cluster, not just collectives that plumb their own
    /// `start_delays` (docs/SCENARIOS.md §Stragglers).
    pub compute_delays: Vec<SimTime>,
    /// Worker threads for the partitioned conservative engine. `None`
    /// (default) runs the legacy single event loop. `Some(n)` partitions
    /// the cluster by leaf/pod (see [`PartitionMap`]) and executes the
    /// SAME windowed algorithm on `n` threads — `Some(1)` runs it
    /// sequentially, so merged results are byte-identical for any `n`
    /// (docs/PERF.md §Partitioned engine). Single-switch topologies have
    /// one partition and fall back to the legacy loop.
    pub cores: Option<usize>,
}

impl ClusterCfg {
    pub fn new(fabric: FabricCfg, transport: TransportKind) -> ClusterCfg {
        let transport_cfg = TransportCfg::from_fabric(&fabric);
        ClusterCfg {
            fabric,
            transport,
            transport_cfg,
            bg_load: 0.0,
            seed: 1,
            max_sim_time: 120 * crate::sim::SEC,
            scheduler: SchedKind::Wheel,
            train_max: TRAIN_MAX_DEFAULT,
            compute_delays: Vec::new(),
            cores: None,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_bg_load(mut self, load: f64) -> Self {
        self.bg_load = load;
        self
    }

    pub fn with_scheduler(mut self, scheduler: SchedKind) -> Self {
        self.scheduler = scheduler;
        self
    }

    pub fn with_train_max(mut self, train_max: usize) -> Self {
        self.train_max = train_max.max(1);
        self
    }

    /// Select the CC algorithm as an explicit experiment choice: the
    /// transports must not substitute their paper-default scheme (CC
    /// ablations and the `cc_sweep` grid run through this). Delegates to
    /// `TransportCfg::with_cc` so packet and fluid cells encode the
    /// forced-CC intent identically.
    pub fn with_cc(mut self, cc: crate::cc::CcKind) -> Self {
        self.transport_cfg = self.transport_cfg.clone().with_cc(cc);
        self
    }

    /// Inject per-rank compute delays (straggler choreography).
    pub fn with_compute_delays(mut self, delays: Vec<SimTime>) -> Self {
        self.compute_delays = delays;
        self
    }

    /// Run the partitioned conservative engine on `cores` worker threads
    /// (0 is treated as 1). The partition cut is fixed by the topology, so
    /// the core count changes wall-clock time only — never results.
    pub fn with_cores(mut self, cores: usize) -> Self {
        self.cores = Some(cores.max(1));
        self
    }
}

/// The simulated cluster.
pub struct Cluster {
    pub cfg: ClusterCfg,
    pub time: SimTime,
    pub events: EventSink,
    pub fabric: Fabric,
    pub mem: MemPool,
    pub metrics: Metrics,
    pub rng: Pcg64,
    nics: Vec<Nic>,
    cqs: Vec<CompletionQueue>,
    srqs: Vec<Srq>,
    transports: Vec<Option<Box<dyn Transport>>>,
    apps: Vec<Option<Box<dyn App>>>,
    bg: Option<BgTraffic>,
    /// First global host id of this shard's partition (0 for the legacy
    /// engine): per-shard background generators draw local host indices.
    bg_port_base: NodeId,
    pfc_required: bool,
    next_qpn: u32,
    pub events_processed: u64,
    /// Reusable completion-drain buffer (verbs v2 `poll_into` hot loop).
    cq_scratch: Vec<CqEvent>,
    /// Freelists (§Perf): emptied serialization-train buffers and consumed
    /// control-message boxes, recycled instead of freed. Per-cluster (so
    /// per-shard in the partitioned engine — worker threads never share).
    train_pool: Vec<Vec<TrainPkt>>,
    ctrl_pool: Vec<Box<CtrlMsg>>,
    /// Per-node armed transport timers (timer_id → live generation) for
    /// generation-stamped lazy cancellation.
    timers: Vec<HashMap<u64, u64>>,
    /// Cluster-global timer generation source.
    timer_gen: u64,
    /// An app was dispatched since the last completion poll (§Perf: gates
    /// the O(nodes) `apps_done` scan in the run loop).
    apps_dirty: bool,
    /// Partitioned-run overhead accounting (null-message cost), summed
    /// over shards at merge and accumulated across runs. Deliberately
    /// NOT part of `Metrics`: the bench harness reads these, the
    /// byte-identity fingerprint does not.
    pub part_epochs: u64,
    pub part_envelopes: u64,
    pub part_envelope_bytes: u64,
}

impl Cluster {
    pub fn new(mut cfg: ClusterCfg) -> Cluster {
        // the engine keeps its own copy of the fabric cfg for host-side
        // serialization — heal the cached integer rate here too, so a
        // caller who wrote `fab.link_gbps = …` directly can never run
        // host links and switch ports at different rates
        cfg.fabric.ser_ps_per_byte = crate::net::ps_per_byte(cfg.fabric.link_gbps);
        let nodes = cfg.fabric.nodes;
        let mut rng = Pcg64::new(cfg.seed, 0xc1u64);
        let fabric = Fabric::new(cfg.fabric.clone());
        let transports: Vec<Option<Box<dyn Transport>>> = (0..nodes)
            .map(|n| Some(cfg.transport.build(n, &cfg.transport_cfg)))
            .collect();
        let pfc_required = transports[0].as_ref().unwrap().requires_pfc();
        let bg = if cfg.bg_load > 0.0 {
            Some(BgTraffic::new(
                crate::net::traffic::BgTrafficCfg {
                    load: cfg.bg_load,
                    ..Default::default()
                },
                nodes,
                cfg.fabric.link_gbps,
                rng.fork(0xb6),
            ))
        } else {
            None
        };
        let mut c = Cluster {
            time: 0,
            events: EventSink::single(cfg.scheduler),
            fabric,
            mem: MemPool::new(),
            metrics: Metrics::new(),
            rng,
            nics: (0..nodes).map(|_| Nic::new(nodes)).collect(),
            cqs: (0..nodes).map(|_| CompletionQueue::default()).collect(),
            srqs: (0..nodes).map(|_| Srq::default()).collect(),
            transports,
            apps: (0..nodes).map(|_| None).collect(),
            bg,
            bg_port_base: 0,
            pfc_required,
            next_qpn: 1,
            events_processed: 0,
            cq_scratch: Vec::with_capacity(64),
            train_pool: Vec::new(),
            ctrl_pool: Vec::new(),
            timers: (0..nodes).map(|_| HashMap::new()).collect(),
            timer_gen: 0,
            apps_dirty: false,
            part_epochs: 0,
            part_envelopes: 0,
            part_envelope_bytes: 0,
            cfg,
        };
        if let Some(bg) = &c.bg {
            c.events.push(bg.next_arrival_ns, Event::BgArrival);
        }
        c
    }

    pub fn nodes(&self) -> usize {
        self.cfg.fabric.nodes
    }

    /// Create a connected QP pair between two nodes; returns the typed
    /// handles (`a`'s end, `b`'s end) applications post through.
    pub fn connect(&mut self, a: NodeId, b: NodeId, qp_type: QpType) -> (QpHandle, QpHandle) {
        let qpn_a = self.next_qpn;
        let qpn_b = self.next_qpn + 1;
        self.next_qpn += 2;
        let mtu = self.cfg.transport_cfg.mtu;
        self.transports[a].as_mut().unwrap().create_qp(Qp {
            qpn: qpn_a,
            qp_type,
            peer_node: b,
            peer_qpn: qpn_b,
            mtu,
        });
        self.transports[b].as_mut().unwrap().create_qp(Qp {
            qpn: qpn_b,
            qp_type,
            peer_node: a,
            peer_qpn: qpn_a,
            mtu,
        });
        (
            QpHandle { qpn: qpn_a, peer: b },
            QpHandle { qpn: qpn_b, peer: a },
        )
    }

    /// Entries consumed from a node's shared receive queue so far.
    pub fn srq_consumed(&self, node: NodeId) -> u64 {
        self.srqs[node].consumed
    }

    /// Install the application for a node.
    pub fn set_app(&mut self, node: NodeId, app: Box<dyn App>) {
        self.apps[node] = Some(app);
    }

    /// Take an app back out (to read results after a run).
    pub fn take_app(&mut self, node: NodeId) -> Option<Box<dyn App>> {
        self.apps[node].take()
    }

    pub fn transport(&self, node: NodeId) -> &dyn Transport {
        self.transports[node].as_deref().unwrap()
    }

    pub fn transport_mut(&mut self, node: NodeId) -> &mut dyn Transport {
        self.transports[node].as_deref_mut().unwrap()
    }

    /// Start all installed apps (schedules their `on_start` at current time).
    pub fn start_apps(&mut self) {
        for node in 0..self.nodes() {
            if self.apps[node].is_some() {
                // token u64::MAX is reserved as the start signal
                self.events.push(
                    self.time,
                    Event::AppWake {
                        node,
                        token: u64::MAX,
                    },
                );
            }
        }
    }

    /// Run until all apps report done, the queue drains, or limits hit.
    /// Returns true if all apps completed.
    ///
    /// With `cfg.cores` set and a multi-tier topology, this dispatches to
    /// the partitioned conservative engine ([`Cluster::run_partitioned`]);
    /// otherwise the legacy single event loop runs. The partitioned
    /// algorithm is identical for every core count (including 1), so
    /// `--cores N` is a pure wall-clock knob.
    pub fn run(&mut self) -> bool {
        if let Some(cores) = self.cfg.cores {
            let pmap = PartitionMap::new(&self.fabric.topo);
            // zero propagation delay would leave no conservative lookahead
            // window; no real config does that, but fall back safely
            if pmap.n_parts > 1 && self.cfg.fabric.prop_delay_ns > 0 {
                return self.run_partitioned(cores.max(1), pmap);
            }
        }
        self.run_legacy()
    }

    fn run_legacy(&mut self) -> bool {
        let max_time = self.cfg.max_sim_time;
        // §Perf: `apps_done` is O(nodes) dyn calls — poll it only after
        // events that actually dispatched into an app (`apps_dirty`), not
        // before every event pop.
        if self.apps_done() {
            return true;
        }
        loop {
            let Some((t, ev)) = self.events.pop() else {
                return self.apps_done();
            };
            debug_assert!(t >= self.time, "time went backwards");
            self.time = t;
            if self.time > max_time {
                log::warn!("simulation wall hit at {}", crate::sim::fmt_time(max_time));
                return false;
            }
            self.events_processed += 1;
            self.handle(ev);
            if self.apps_dirty {
                self.apps_dirty = false;
                if self.apps_done() {
                    return true;
                }
            }
        }
    }

    /// Keep processing events up to absolute time `t` even after all apps
    /// report done — lets callers drain in-flight packets (e.g. one-sided
    /// WRITEs whose sender completed on transmit).
    pub fn run_until(&mut self, t: SimTime) {
        while let Some(next) = self.events.peek_time() {
            if next > t {
                break;
            }
            let (ts, ev) = self.events.pop().unwrap();
            self.time = ts;
            self.events_processed += 1;
            self.handle(ev);
        }
        self.time = self.time.max(t.min(self.time + 1));
    }

    fn apps_done(&self) -> bool {
        self.apps
            .iter()
            .all(|a| a.as_ref().map(|a| a.is_done()).unwrap_or(true))
    }

    // ---- partitioned conservative engine -----------------------------------
    //
    // Single-run multi-core DES (docs/PERF.md §Partitioned engine): the
    // cluster is cut along its topology tiers (one partition per leaf or
    // pod — see `PartitionMap`), each partition becomes a shard `Cluster`
    // with its own event queue, RNG stream, metrics, and memory replica,
    // and shards advance in lockstep conservative windows of width L =
    // `prop_delay_ns` (the minimum latency of any cross-partition hop).
    // Inside a window every shard executes independently; events bound
    // for another partition — switch→switch hops, ctrl-channel and pause
    // deliveries — always land >= L in the future, so they are exchanged
    // as `(time, origin, seq)`-stamped envelopes at the window barrier
    // and inserted before the receiver's next window. Event keys make the
    // interleaving a pure function of the partition cut, so `--cores 1`
    // and `--cores N` produce byte-identical merged metrics.

    /// Execute one conservative window: handle every event strictly
    /// before `window_end`. Returns true if the simulation wall was hit
    /// (the event is dropped, exactly like the legacy loop's abort).
    fn run_window(&mut self, window_end: SimTime, max_time: SimTime) -> bool {
        if let Some(r) = &mut self.events.route {
            r.window_end = window_end;
        }
        while let Some(t) = self.events.peek_time() {
            if t >= window_end {
                break;
            }
            let (ts, ev) = self.events.pop().unwrap();
            debug_assert!(ts >= self.time, "time went backwards");
            self.time = ts;
            if ts > max_time {
                log::warn!("simulation wall hit at {}", crate::sim::fmt_time(max_time));
                return true;
            }
            self.events_processed += 1;
            self.handle(ev);
        }
        false
    }

    /// Move every sealed outbox out of this shard: payload-refresh spans
    /// are read from the shard's memory replica NOW (end of window — the
    /// run is over for these bytes until the envelope's receive time).
    fn take_sealed_outboxes(&mut self) -> Vec<(usize, Vec<Envelope>)> {
        let Some(r) = &mut self.events.route else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for dest in 0..r.outbox.len() {
            if r.outbox[dest].is_empty() {
                continue;
            }
            let mut envs = std::mem::take(&mut r.outbox[dest]);
            for e in &mut envs {
                if let Some(Refresh::Span(mr, off, len)) = e.refresh {
                    let bytes = self.mem.read(mr, off, len).to_vec().into_boxed_slice();
                    e.refresh = Some(Refresh::Bytes(mr, off, bytes));
                }
            }
            out.push((dest, envs));
        }
        out
    }

    /// Deliver a window's incoming envelopes: sort by `(time, origin,
    /// seq)` — the global tie-break order — apply payload refreshes in
    /// that same order, and insert the events with their original keys.
    fn deliver_envelopes(&mut self, mut envs: Vec<Envelope>) {
        envs.sort_unstable_by_key(|e| (e.time, e.origin, e.seq));
        self.part_envelopes += envs.len() as u64;
        for e in envs {
            let Envelope {
                time,
                origin,
                seq,
                ev,
                refresh,
            } = e;
            if let Some(Refresh::Bytes(mr, off, bytes)) = refresh {
                self.part_envelope_bytes += bytes.len() as u64;
                self.mem.write(mr, off, &bytes);
            }
            self.events.push_prekeyed(time, (origin, seq), ev);
        }
    }

    /// Split this fully set-up cluster into one shard per partition. The
    /// root queue's pending (setup) events are distributed by owner with
    /// their original keys; every piece of per-node state moves to its
    /// owner's shard; shard RNGs fork from the root stream in fixed
    /// partition order.
    fn split_shards(&mut self, pmap: &Arc<PartitionMap>) -> Vec<Cluster> {
        let n_parts = pmap.n_parts;
        let nodes = self.nodes();
        let setup = self.events.q.drain();
        let seq0 = self.events.q.seq();
        let mut shards: Vec<Cluster> = (0..n_parts)
            .map(|p| {
                let mut rng = self.rng.fork(p as u64);
                let bg = if self.cfg.bg_load > 0.0 {
                    Some(BgTraffic::new(
                        crate::net::traffic::BgTrafficCfg {
                            load: self.cfg.bg_load,
                            ..Default::default()
                        },
                        pmap.hosts_per_part(),
                        self.cfg.fabric.link_gbps,
                        rng.fork(0xb6),
                    ))
                } else {
                    None
                };
                Cluster {
                    cfg: self.cfg.clone(),
                    time: self.time,
                    events: EventSink::sharded(
                        self.cfg.scheduler,
                        p as u32,
                        Arc::clone(pmap),
                        seq0,
                    ),
                    fabric: Fabric::new(self.cfg.fabric.clone()),
                    mem: self.mem.clone(),
                    metrics: if p == 0 {
                        // partition 0 inherits any setup-time metrics so
                        // the fixed-order merge reproduces them first
                        std::mem::take(&mut self.metrics)
                    } else {
                        Metrics::new()
                    },
                    rng,
                    nics: (0..nodes).map(|_| Nic::default()).collect(),
                    cqs: (0..nodes).map(|_| CompletionQueue::default()).collect(),
                    srqs: (0..nodes).map(|_| Srq::default()).collect(),
                    transports: (0..nodes).map(|_| None).collect(),
                    apps: (0..nodes).map(|_| None).collect(),
                    bg,
                    bg_port_base: pmap.host_base(p),
                    pfc_required: self.pfc_required,
                    next_qpn: self.next_qpn,
                    events_processed: 0,
                    cq_scratch: Vec::with_capacity(64),
                    train_pool: Vec::new(),
                    ctrl_pool: Vec::new(),
                    timers: (0..nodes).map(|_| HashMap::new()).collect(),
                    timer_gen: self.timer_gen,
                    apps_dirty: false,
                    part_epochs: 0,
                    part_envelopes: 0,
                    part_envelope_bytes: 0,
                }
            })
            .collect();
        // move per-node state to its owner's shard
        for (node, &p) in pmap.node_part.iter().enumerate() {
            let s = &mut shards[p as usize];
            s.nics[node] = std::mem::take(&mut self.nics[node]);
            s.cqs[node] = std::mem::take(&mut self.cqs[node]);
            s.srqs[node] = std::mem::take(&mut self.srqs[node]);
            s.transports[node] = self.transports[node].take();
            s.apps[node] = self.apps[node].take();
            s.timers[node] = std::mem::take(&mut self.timers[node]);
        }
        // distribute setup events by owner, keys intact — except the root
        // BgArrival: each shard runs its own arrival clock
        for (t, key, ev) in setup {
            if matches!(ev, Event::BgArrival) {
                continue;
            }
            let owner = ev_owner(pmap, 0, &ev) as usize;
            shards[owner].events.push_prekeyed(t, key, ev);
        }
        for s in &mut shards {
            if let Some(bg) = &s.bg {
                let t = bg.next_arrival_ns;
                s.events.push(t, Event::BgArrival);
            }
        }
        shards
    }

    /// Fold the shards back into `self` after the windows complete:
    /// metrics merge in fixed partition order (the byte-identity
    /// contract), every memory region is adopted from its node-owner's
    /// replica, per-node and per-link state moves home, and fabric
    /// counters sum.
    fn merge_shards(&mut self, mut shards: Vec<Cluster>, pmap: &PartitionMap) {
        self.metrics = std::mem::take(&mut shards[0].metrics);
        for s in shards.iter_mut().skip(1) {
            let m = std::mem::take(&mut s.metrics);
            self.metrics.merge(&m);
        }
        for idx in 0..self.mem.region_count() {
            let mr = MrId(idx as u32);
            let owner = pmap.node_part[self.mem.node_of(mr)] as usize;
            self.mem.adopt_region(&shards[owner].mem, mr);
        }
        self.time = shards.iter().map(|s| s.time).max().unwrap_or(self.time);
        self.events_processed += shards.iter().map(|s| s.events_processed).sum::<u64>();
        for (node, &p) in pmap.node_part.iter().enumerate() {
            let s = &mut shards[p as usize];
            self.nics[node] = std::mem::take(&mut s.nics[node]);
            self.cqs[node] = std::mem::take(&mut s.cqs[node]);
            self.srqs[node] = std::mem::take(&mut s.srqs[node]);
            self.transports[node] = s.transports[node].take();
            self.apps[node] = s.apps[node].take();
            self.timers[node] = std::mem::take(&mut s.timers[node]);
        }
        for (link, &p) in pmap.link_part.iter().enumerate() {
            self.fabric.ports[link] = std::mem::take(&mut shards[p as usize].fabric.ports[link]);
        }
        for s in &shards {
            self.fabric.drops_overflow += s.fabric.drops_overflow;
            self.fabric.drops_corrupt += s.fabric.drops_corrupt;
            self.fabric.drops_link_down += s.fabric.drops_link_down;
            self.fabric.ecn_marks += s.fabric.ecn_marks;
            self.fabric.pfc_pauses += s.fabric.pfc_pauses;
            self.fabric.forwarded += s.fabric.forwarded;
        }
        self.timer_gen = shards.iter().map(|s| s.timer_gen).max().unwrap_or(0);
        self.part_epochs += shards.iter().map(|s| s.part_epochs).sum::<u64>();
        self.part_envelopes += shards.iter().map(|s| s.part_envelopes).sum::<u64>();
        self.part_envelope_bytes += shards.iter().map(|s| s.part_envelope_bytes).sum::<u64>();
        // in-flight events (a run ends when apps are done, not when the
        // queues drain) come home with keys intact so a post-run
        // `run_until` drains them exactly like the legacy engine; each
        // shard's private BgArrival clock stays behind, mirroring the
        // split. Clear first: the split's drain advanced the root wheel's
        // internal clock, and a reset wheel accepts any (future) time.
        self.events.clear();
        for s in &mut shards {
            for (t, key, ev) in s.events.q.drain() {
                if matches!(ev, Event::BgArrival) {
                    continue;
                }
                self.events.push_prekeyed(t, key, ev);
            }
        }
    }

    /// The partitioned conservative run: split, advance lockstep windows
    /// on `cores` worker threads, merge. Same algorithm for every worker
    /// count — the windows, event keys, and merge order depend only on
    /// the partition cut.
    fn run_partitioned(&mut self, cores: usize, pmap: PartitionMap) -> bool {
        let lookahead = self.cfg.fabric.prop_delay_ns.max(1);
        let max_time = self.cfg.max_sim_time;
        let n_parts = pmap.n_parts;
        let pmap = Arc::new(pmap);
        let mut shards = self.split_shards(&pmap);
        // contiguous shard chunks, one worker thread each (cores = 1 ⇒ a
        // single worker runs every shard — the same code path, serially)
        let chunk = n_parts.div_ceil(cores.min(n_parts));
        let workers = n_parts.div_ceil(chunk);
        let shared = EpochShared {
            inboxes: (0..n_parts).map(|_| Mutex::new(Vec::new())).collect(),
            next_times: shards
                .iter()
                .map(|s| AtomicU64::new(s.events.peek_time().unwrap_or(u64::MAX)))
                .collect(),
            done_flags: shards
                .iter()
                .map(|s| AtomicBool::new(s.apps_done()))
                .collect(),
            wall: AtomicBool::new(false),
            barrier: Barrier::new(workers),
        };
        std::thread::scope(|scope| {
            let mut base = 0usize;
            for chunk_shards in shards.chunks_mut(chunk) {
                let first = base;
                base += chunk_shards.len();
                let shared = &shared;
                scope.spawn(move || {
                    epoch_worker(chunk_shards, first, shared, lookahead, max_time);
                });
            }
        });
        let completed = !shared.wall.load(Ordering::SeqCst)
            && shared
                .done_flags
                .iter()
                .all(|d| d.load(Ordering::SeqCst));
        self.merge_shards(shards, &pmap);
        completed
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::HostTxKick(node) => self.host_tx_kick(node),
            Event::HostTxDone(node, pkt) => {
                self.nics[node].tx_busy = false;
                let arrive = self.time + self.cfg.fabric.prop_delay_ns;
                let sw = self.fabric.topo.ingress_switch(node);
                self.events.push(arrive, Event::SwitchArrive { sw, pkt });
                self.events.push(self.time, Event::HostTxKick(node));
            }
            Event::SwitchArrive { sw, pkt } => self.switch_arrive(sw, pkt),
            Event::PortTxDone(link, pkt) => self.port_tx_done(link, pkt),
            Event::TxTrainDone { idx, port, train } => {
                self.tx_train_done(idx, port, train)
            }
            Event::TxTrainFree { idx, port } => {
                if port {
                    self.fabric.ports[idx].busy = false;
                    self.port_start_tx(idx);
                    self.maybe_pfc_update(idx);
                } else {
                    self.nics[idx].tx_busy = false;
                    self.host_tx_kick(idx);
                }
            }
            Event::HostRx(pkt) => self.host_rx(pkt),
            Event::TransportTimer { node, timer_id, gen } => {
                if self.timers[node].get(&timer_id) == Some(&gen) {
                    self.timers[node].remove(&timer_id);
                    self.metrics.timer_fires += 1;
                    self.with_transport(node, |t, ctx| t.on_timer(ctx, timer_id));
                    self.drain_cqes(node);
                } else {
                    // re-armed or cancelled since scheduling: drop here,
                    // never dispatch (generation-stamped lazy cancellation)
                    self.metrics.timer_stale_drops += 1;
                }
            }
            Event::AppWake { node, token } => {
                if token == u64::MAX {
                    self.with_app(node, |a, ctx| a.on_start(ctx));
                } else {
                    self.with_app(node, |a, ctx| a.on_wake(ctx, token));
                }
                self.drain_cqes(node);
            }
            Event::BgArrival => self.bg_arrival(),
            Event::BgInject { port, size } => self.bg_inject(port, size),
            Event::PfcUpdate { link } => self.pfc_update(link),
            Event::NetFault(fault) => self.net_fault(fault),
            Event::SrqDeadline { node, entry_id } => {
                // entry already consumed by an arriving message ⇒ no-op;
                // its fate is the per-message deadline armed at activation
                if let Some(wqe) = self.srqs[node].remove(entry_id) {
                    self.metrics.partial_completions += 1;
                    self.cqs[node].push_event(CqEvent::TimeoutFired {
                        wr_id: wqe.wr_id,
                        qpn: 0, // queue-level: the entry never bound to a QP
                        is_recv: true,
                        delivered_bytes: 0,
                        expected_bytes: wqe.total_len(),
                        time: self.time,
                    });
                    self.drain_cqes(node);
                }
            }
            Event::InjectFault { node } => {
                let mut t = self.transports[node].take().expect("transport");
                let desc = t.inject_fault(&mut self.rng);
                self.transports[node] = Some(t);
                if let Some(d) = desc {
                    log::debug!("fault injected @{}: {d}", crate::sim::fmt_time(self.time));
                    self.metrics.bump("faults_injected");
                } else {
                    self.metrics.bump("faults_no_target");
                }
            }
        }
    }

    /// Schedule an SEU-style fault injection at an absolute sim time. The
    /// victim node is drawn here, at scheduling time, so the campaign is
    /// a fixed part of the event schedule (and the event routes to one
    /// partition under the partitioned engine).
    pub fn schedule_fault(&mut self, at: SimTime) {
        let node = self.rng.index(self.nodes());
        self.events.push(at, Event::InjectFault { node });
    }

    /// Total QPs currently stalled across all NICs.
    pub fn total_stalled_qps(&self) -> usize {
        self.transports
            .iter()
            .map(|t| t.as_ref().map(|t| t.stalled_qps()).unwrap_or(0))
            .sum()
    }

    // ---- host NIC egress ---------------------------------------------------

    fn host_tx_kick(&mut self, node: NodeId) {
        let train_max = self.cfg.train_max.max(1);
        let nic = &mut self.nics[node];
        if nic.tx_busy {
            return;
        }
        let Some(first) = nic.pop_egress() else { return };
        nic.tx_busy = true;
        let mut done = self.time + self.cfg.fabric.serialize_ns(first.size);
        if train_max <= 1 || !nic.has_egress() {
            // single packet: classic per-packet serialization round-trip
            self.events.push(done, Event::HostTxDone(node, first));
            return;
        }
        // §Perf: coalesce back-to-back egress into one packet train — one
        // scheduler round-trip for the burst instead of a HostTxDone +
        // re-kick per packet; per-packet finish times are reconstructed
        // arithmetically from cumulative serialization delays. The train
        // buffer comes from the per-cluster freelist (refilled by
        // `tx_train_done`), so steady-state trains allocate nothing.
        let first_done = done;
        let mut train = self.train_pool.pop().unwrap_or_default();
        train.push(TrainPkt {
            pkt: first,
            done_at: done,
        });
        while train.len() < train_max {
            let Some(p) = nic.pop_egress() else { break };
            done += self.cfg.fabric.serialize_ns(p.size);
            train.push(TrainPkt {
                pkt: p,
                done_at: done,
            });
        }
        self.metrics.tx_trains += 1;
        self.metrics.tx_train_pkts += train.len() as u64;
        self.events.push(
            first_done,
            Event::TxTrainDone {
                idx: node,
                port: false,
                train,
            },
        );
    }

    /// A serialization train's first packet finished: emit every packet's
    /// downstream event at its reconstructed time (all >= now), then free
    /// the link at the last packet's finish time.
    fn tx_train_done(&mut self, idx: usize, port: bool, mut train: Vec<TrainPkt>) {
        let prop = self.cfg.fabric.prop_delay_ns;
        let mut last = self.time;
        if port {
            for tp in train.drain(..) {
                last = tp.done_at;
                // per-packet corruption/jitter in train order keeps RNG
                // consumption deterministic
                self.forward_from(idx, tp.done_at, tp.pkt);
            }
        } else {
            let sw = self.fabric.topo.ingress_switch(idx);
            for tp in train.drain(..) {
                last = tp.done_at;
                self.events
                    .push(tp.done_at + prop, Event::SwitchArrive { sw, pkt: tp.pkt });
            }
        }
        self.events.push(last, Event::TxTrainFree { idx, port });
        // recycle the emptied buffer (capacity kept) into the freelist
        if self.train_pool.len() < TRAIN_POOL_MAX {
            self.metrics.pool_recycles += 1;
            self.train_pool.push(train);
        }
    }

    // ---- switch ------------------------------------------------------------

    /// A packet hit switch `sw`'s ingress: route it to its next-hop
    /// egress link (ECMP/spray happens inside `Fabric::route`) and queue.
    fn switch_arrive(&mut self, sw: SwitchCode, pkt: Packet) {
        let link = self.fabric.route(sw, &pkt, &mut self.rng);
        let was_idle = !self.fabric.ports[link].busy;
        match self.fabric.enqueue(link, pkt, &mut self.rng) {
            EnqueueOutcome::Dropped => {
                // attribute the loss: a dead link's blackhole is a fault
                // effect, not a congestion drop — fault experiments read
                // these as separate causes
                if self.fabric.ports[link].up {
                    self.metrics.pkts_dropped_queue += 1;
                } else {
                    self.metrics.add("pkts_dropped_link_down", 1);
                }
            }
            EnqueueOutcome::Queued { .. } => {
                if was_idle {
                    self.port_start_tx(link);
                }
            }
        }
        self.maybe_pfc_update(link);
    }

    /// A packet finished serializing on `link` at `done_at`: deliver it
    /// downstream — to the host NIC (after the corruption lottery + the
    /// single-tier spray-jitter stand-in) or to the next switch tier.
    fn forward_from(&mut self, link: LinkId, done_at: SimTime, pkt: Packet) {
        let prop = self.cfg.fabric.prop_delay_ns;
        match self.fabric.link_dst(link) {
            LinkDst::Host(_) => {
                if self.fabric.corrupted(&pkt, &mut self.rng) {
                    self.metrics.pkts_dropped_corrupt += 1;
                    return;
                }
                let jitter = self.fabric.spray_delay(&pkt, &mut self.rng);
                self.events.push(done_at + prop + jitter, Event::HostRx(pkt));
            }
            LinkDst::Leaf(l) => {
                let sw = self.fabric.topo.sw_leaf(l);
                self.events.push(done_at + prop, Event::SwitchArrive { sw, pkt });
            }
            LinkDst::Spine(s) => {
                let sw = self.fabric.topo.sw_spine(s);
                self.events.push(done_at + prop, Event::SwitchArrive { sw, pkt });
            }
            LinkDst::Core(c) => {
                let sw = self.fabric.topo.sw_core(c);
                self.events.push(done_at + prop, Event::SwitchArrive { sw, pkt });
            }
        }
    }

    /// Schedule a per-port PFC re-evaluation only when that edge port
    /// crossed a threshold — unconditional per-packet scheduling floods
    /// the event queue, and core ports rely on ECN/drops rather than PFC
    /// (docs/TOPOLOGY.md §PFC).
    fn maybe_pfc_update(&mut self, link: LinkId) {
        if !self.pfc_required || !self.fabric.topo.is_edge(link) {
            return;
        }
        let asserted = self.fabric.ports[link].pfc_asserted;
        if (!asserted && self.fabric.pfc_should_pause(link))
            || (asserted && self.fabric.pfc_should_resume(link))
        {
            self.events.push(self.time, Event::PfcUpdate { link });
        }
    }

    fn port_start_tx(&mut self, link: LinkId) {
        let train_max = self.cfg.train_max.max(1);
        let mbps = self.fabric.link_mbps(link);
        let qlen = self.fabric.queue_bytes(link);
        let Some(mut pkt) = self.fabric.dequeue(link) else {
            self.fabric.ports[link].busy = false;
            return;
        };
        // stamp/accumulate the uniform telemetry header (NetHints) on
        // data packets: bottleneck queue depth, CE mark, port busy-time
        // proxy, link rate — the one code path every CC scheme's in-band
        // signals come from
        Fabric::stamp_hints(&mut pkt, qlen, self.fabric.ports[link].tx_bytes, mbps);
        self.fabric.ports[link].busy = true;
        let mut done = self.time + self.fabric.port_tx_ns(link, &pkt);
        if train_max <= 1 || self.fabric.ports[link].queue.is_empty() {
            self.events.push(done, Event::PortTxDone(link, pkt));
            return;
        }
        // §Perf: train the egress too — dequeue the burst now with
        // arithmetic finish times (switch delay + serialization each);
        // telemetry is stamped from the residual queue before each
        // packet's own dequeue, approximating the staggered drain.
        let first_done = done;
        let mut train = self.train_pool.pop().unwrap_or_default();
        train.push(TrainPkt { pkt, done_at: done });
        while train.len() < train_max {
            let qlen = self.fabric.queue_bytes(link);
            let Some(mut pkt) = self.fabric.dequeue(link) else { break };
            Fabric::stamp_hints(&mut pkt, qlen, self.fabric.ports[link].tx_bytes, mbps);
            done += self.fabric.port_tx_ns(link, &pkt);
            train.push(TrainPkt { pkt, done_at: done });
        }
        self.metrics.tx_trains += 1;
        self.metrics.tx_train_pkts += train.len() as u64;
        self.events.push(
            first_done,
            Event::TxTrainDone {
                idx: link,
                port: true,
                train,
            },
        );
    }

    fn port_tx_done(&mut self, link: LinkId, pkt: Packet) {
        // next packet on this link
        self.fabric.ports[link].busy = false;
        self.port_start_tx(link);
        self.maybe_pfc_update(link);
        self.forward_from(link, self.time, pkt);
    }

    // ---- host NIC ingress ----------------------------------------------------

    fn host_rx(&mut self, pkt: Packet) {
        let node = pkt.dst;
        match pkt.kind {
            PktKind::Pause { xoff, for_dst } => {
                let nic = &mut self.nics[node];
                if xoff && !nic.paused_dsts[for_dst] {
                    nic.paused_dsts[for_dst] = true;
                    nic.paused_since[for_dst] = self.time;
                    self.metrics.pfc_pause_events += 1;
                } else if !xoff && nic.paused_dsts[for_dst] {
                    nic.paused_dsts[for_dst] = false;
                    self.metrics.pfc_paused_ns += self.time - nic.paused_since[for_dst];
                    self.events.push(self.time, Event::HostTxKick(node));
                }
            }
            PktKind::Bg => { /* other tenants' traffic: sunk */ }
            PktKind::Ctrl(mut msg) => {
                let from = pkt.src;
                let m = std::mem::replace(
                    &mut *msg,
                    CtrlMsg {
                        tag: 0,
                        payload: Vec::new(),
                    },
                );
                self.with_app(node, |a, ctx| a.on_ctrl(ctx, from, m));
                // the emptied box shell goes back to the ctrl freelist for
                // `AppCtx::send_ctrl` to refill without a heap round-trip
                if self.ctrl_pool.len() < CTRL_POOL_MAX {
                    self.metrics.pool_recycles += 1;
                    self.ctrl_pool.push(msg);
                }
                self.drain_cqes(node);
            }
            _ => {
                if let PktKind::Data(h) = &pkt.kind {
                    self.metrics.pkts_delivered += 1;
                    let _ = h;
                }
                self.with_transport(node, |t, ctx| t.on_packet(ctx, pkt));
                self.drain_cqes(node);
            }
        }
    }

    // ---- PFC ------------------------------------------------------------------

    /// Per-port PFC transition: assert when THIS edge port crossed XOFF,
    /// release when it drained below XON. (Pre-fix, one global flag keyed
    /// on `any`/`all` ports paused every sender in the cluster — the
    /// head-of-line amplification this PR removes.)
    fn pfc_update(&mut self, link: LinkId) {
        let asserted = self.fabric.ports[link].pfc_asserted;
        if !asserted && self.fabric.pfc_should_pause(link) {
            self.fabric.ports[link].pfc_asserted = true;
            self.fabric.pfc_pauses += 1;
            self.broadcast_pause(link, true);
        } else if asserted && self.fabric.pfc_should_resume(link) {
            self.fabric.ports[link].pfc_asserted = false;
            self.broadcast_pause(link, false);
        }
    }

    /// Deliver per-destination pause/resume frames: every host learns the
    /// state of destination `for_dst` (edge link id == host id), but only
    /// traffic actually headed there blocks at the sender FIFO.
    fn broadcast_pause(&mut self, for_dst: NodeId, xoff: bool) {
        for node in 0..self.nodes() {
            let pkt = Packet {
                src: node, // nominal
                dst: node,
                size: 64,
                ecn: false,
                spray: false,
                kind: PktKind::Pause { xoff, for_dst },
            };
            self.events
                .push(self.time + self.cfg.fabric.prop_delay_ns, Event::HostRx(pkt));
        }
    }

    // ---- link-level faults ----------------------------------------------------

    /// Apply a link-level fault. `LinkDown` schedules its own routing
    /// convergence (`RerouteOut` after `reroute_ns`); until that fires,
    /// ECMP/spray keep hashing flows onto the dead link — the
    /// pre-convergence blackhole window real fabrics suffer.
    fn net_fault(&mut self, fault: NetFault) {
        match fault {
            NetFault::LinkDown(link) => {
                let flushed = self.fabric.link_down(link);
                if flushed > 0 {
                    self.metrics.add("pkts_dropped_link_down", flushed as u64);
                }
                self.metrics.bump("net_faults");
                self.events.push(
                    self.time + self.cfg.fabric.reroute_ns,
                    Event::NetFault(NetFault::RerouteOut(link)),
                );
                // a downed edge port just emptied: release any PFC it held
                self.maybe_pfc_update(link);
            }
            NetFault::LinkUp(link) => {
                self.fabric.link_up(link);
                self.metrics.bump("net_faults");
                if !self.fabric.ports[link].busy && !self.fabric.ports[link].queue.is_empty()
                {
                    self.port_start_tx(link);
                }
            }
            NetFault::RerouteOut(link) => self.fabric.reroute_out(link),
            NetFault::Degrade(link, factor) => {
                self.fabric.degrade_link(link, factor);
                self.metrics.bump("net_faults");
            }
        }
    }

    /// Schedule a link-level fault at an absolute sim time (scenario
    /// builders — flap, spine failure, degrade — live in `hw::fault`).
    pub fn schedule_net_fault(&mut self, at: SimTime, fault: NetFault) {
        self.events.push(at, Event::NetFault(fault));
    }

    /// Choreographed incast microburst: `bytes` of cross-traffic converge
    /// on `dst`'s edge port from `at` on, as back-to-back `pkt_size`
    /// packets. Rides the background-traffic injection path
    /// (`Event::BgInject`), so the burst contends for queue space and
    /// bandwidth like any other tenant — and obeys PFC and the
    /// deep-queue backoff the same way. Consumes no RNG at scheduling
    /// time: the burst is part of the deterministic event schedule.
    pub fn schedule_incast(&mut self, at: SimTime, dst: NodeId, bytes: usize, pkt_size: usize) {
        let pkt = pkt_size.max(256);
        let mut off: SimTime = 0;
        let mut left = bytes;
        while left > 0 {
            let size = left.min(pkt);
            self.events.push(at + off, Event::BgInject { port: dst, size });
            // 1 ns apart: a fixed arrival order without artificial ties
            off += 1;
            left -= size;
        }
    }

    // ---- background traffic ----------------------------------------------------

    fn bg_arrival(&mut self) {
        let Some(bg) = &mut self.bg else { return };
        let flow = bg.next_flow(self.time);
        let pkts = bg.packetize(&flow);
        let next = bg.next_arrival_ns;
        // `flow.port` is local to this shard's host range; `bg_port_base`
        // (0 for the single-core engine) rebases it to the global edge
        // port, so each partition's tenant load targets its own hosts.
        for (off, size) in pkts {
            self.events.push(
                self.time + off,
                Event::BgInject {
                    port: flow.port + self.bg_port_base,
                    size,
                },
            );
        }
        self.events.push(next, Event::BgArrival);
    }

    fn bg_inject(&mut self, port: NodeId, size: usize) {
        // Background packets occupy queue space and port bandwidth but are
        // sunk at the host NIC (they belong to other tenants; they land
        // directly on the destination's edge port — the incast locus —
        // in every topology). Under PFC (lossless class), tenants headed
        // to a paused port stop injecting too — otherwise the fabric
        // deadlocks with that queue pinned above XOFF forever. Per-port:
        // an unrelated paused port no longer silences this tenant.
        if self.pfc_required && self.fabric.ports[port].pfc_asserted {
            return;
        }
        // Background tenants run their own congestion control (DCQCN et
        // al.): once the port queue is deep they back off rather than
        // blasting open-loop into a full buffer.
        if self.fabric.queue_bytes(port) > self.cfg.fabric.queue_cap_bytes / 2 {
            return;
        }
        let pkt = Packet {
            src: port,
            dst: port,
            size: size + crate::net::WIRE_HDR_BYTES,
            ecn: false,
            spray: false,
            kind: PktKind::Bg,
        };
        let was_idle = !self.fabric.ports[port].busy;
        match self.fabric.enqueue(port, pkt, &mut self.rng) {
            EnqueueOutcome::Dropped => {}
            EnqueueOutcome::Queued { .. } => {
                if was_idle {
                    self.port_start_tx(port);
                }
            }
        }
        self.maybe_pfc_update(port);
    }

    // ---- dispatch plumbing -------------------------------------------------------

    fn with_transport<R>(
        &mut self,
        node: NodeId,
        f: impl FnOnce(&mut dyn Transport, &mut NicCtx) -> R,
    ) -> R {
        let mut t = self.transports[node].take().expect("transport reentrancy");
        let mut ctx = NicCtx {
            time: self.time,
            node,
            mem: &mut self.mem,
            cq: &mut self.cqs[node],
            metrics: &mut self.metrics,
            rng: &mut self.rng,
            events: &mut self.events,
            nic: &mut self.nics[node],
            srq: &mut self.srqs[node],
            timers: &mut self.timers[node],
            timer_gen: &mut self.timer_gen,
        };
        let r = f(t.as_mut(), &mut ctx);
        self.transports[node] = Some(t);
        r
    }

    fn with_app<R>(
        &mut self,
        node: NodeId,
        f: impl FnOnce(&mut dyn App, &mut AppCtx) -> R,
    ) -> Option<R> {
        let mut a = self.apps[node].take()?;
        let mut t = self.transports[node].take().expect("transport reentrancy");
        let r = {
            let mut ctx = AppCtx {
                time: self.time,
                node,
                mem: &mut self.mem,
                metrics: &mut self.metrics,
                rng: &mut self.rng,
                events: &mut self.events,
                nic: &mut self.nics[node],
                transport: t.as_mut(),
                cq: &mut self.cqs[node],
                srq: &mut self.srqs[node],
                timers: &mut self.timers[node],
                timer_gen: &mut self.timer_gen,
                base_rtt_ns: self.cfg.fabric.base_rtt_ns(),
                ctrl_pool: &mut self.ctrl_pool,
            };
            f(a.as_mut(), &mut ctx)
        };
        self.transports[node] = Some(t);
        self.apps[node] = Some(a);
        self.apps_dirty = true;
        Some(r)
    }

    /// Deliver pending completion events to the node's app via the
    /// non-allocating `poll_into` path (one scratch vector reused across
    /// every poll of the run). Loops because app reactions can
    /// synchronously produce more completions.
    fn drain_cqes(&mut self, node: NodeId) {
        for _ in 0..64 {
            if self.cqs[node].is_empty() {
                return;
            }
            let mut scratch = std::mem::take(&mut self.cq_scratch);
            scratch.clear();
            self.cqs[node].poll_into(&mut scratch);
            for ev in scratch.drain(..) {
                self.with_app(node, |a, ctx| a.on_cq_event(ctx, ev));
            }
            self.cq_scratch = scratch;
        }
        panic!("CQE drain livelock on node {node}");
    }
}

/// Lockstep window coordination between shard workers: per-shard inboxes
/// for cross-partition envelopes, the published next-event time and
/// apps-done flag of every shard, the wall flag, and the epoch barrier.
struct EpochShared {
    inboxes: Vec<Mutex<Vec<Envelope>>>,
    /// Next pending event time per shard (`u64::MAX` = drained).
    next_times: Vec<AtomicU64>,
    done_flags: Vec<AtomicBool>,
    wall: AtomicBool,
    barrier: Barrier,
}

/// One worker's epoch loop over its contiguous shard chunk (`first` is
/// the global index of `shards[0]`). Every worker computes the SAME
/// window bound from the shared state, so no coordinator thread exists:
///
/// 1. run each owned shard to the window end, flush its sealed outboxes
///    into the destination inboxes;
/// 2. barrier — every cross-partition envelope of this window is posted;
/// 3. drain each owned shard's inbox (sorted, payload refreshes applied),
///    publish its next event time and done flag;
/// 4. barrier — every worker sees identical published state, loops.
fn epoch_worker(
    shards: &mut [Cluster],
    first: usize,
    shared: &EpochShared,
    lookahead: SimTime,
    max_time: SimTime,
) {
    loop {
        let mut t0 = u64::MAX;
        for t in &shared.next_times {
            t0 = t0.min(t.load(Ordering::SeqCst));
        }
        let all_done = shared
            .done_flags
            .iter()
            .all(|d| d.load(Ordering::SeqCst));
        if all_done || shared.wall.load(Ordering::SeqCst) || t0 == u64::MAX {
            return;
        }
        if t0 > max_time {
            // the next event anywhere would cross the wall: abort exactly
            // where the legacy loop would
            shared.wall.store(true, Ordering::SeqCst);
            return;
        }
        let window_end = t0.saturating_add(lookahead);
        if first == 0 {
            // one worker stamps epoch count (merged additively later)
            shards[0].part_epochs += 1;
        }
        for s in shards.iter_mut() {
            if s.run_window(window_end, max_time) {
                shared.wall.store(true, Ordering::SeqCst);
            }
            for (dest, envs) in s.take_sealed_outboxes() {
                shared.inboxes[dest].lock().unwrap().extend(envs);
            }
        }
        shared.barrier.wait();
        for (i, s) in shards.iter_mut().enumerate() {
            let p = first + i;
            let inbox = std::mem::take(&mut *shared.inboxes[p].lock().unwrap());
            if !inbox.is_empty() {
                s.deliver_envelopes(inbox);
            }
            shared.next_times[p].store(
                s.events.peek_time().unwrap_or(u64::MAX),
                Ordering::SeqCst,
            );
            shared.done_flags[p].store(s.apps_done(), Ordering::SeqCst);
        }
        shared.barrier.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Engine-level smoke test with a null app; transports are exercised in
    /// `transport::*` and `rust/tests/`.
    struct NullApp {
        done: bool,
    }

    impl App for NullApp {
        fn on_start(&mut self, ctx: &mut AppCtx) {
            // wake once and finish
            ctx.wake_in(100, 1);
        }
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, _ev: CqEvent) {}
        fn on_wake(&mut self, _ctx: &mut AppCtx, token: u64) {
            assert_eq!(token, 1);
            self.done = true;
        }
        fn on_ctrl(&mut self, _ctx: &mut AppCtx, _from: NodeId, _msg: CtrlMsg) {}
        fn is_done(&self) -> bool {
            self.done
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    #[test]
    fn run_completes_null_apps() {
        let cfg = ClusterCfg::new(FabricCfg::cloudlab(2), TransportKind::Optinic);
        let mut c = Cluster::new(cfg);
        c.set_app(0, Box::new(NullApp { done: false }));
        c.set_app(1, Box::new(NullApp { done: false }));
        c.start_apps();
        assert!(c.run());
        assert_eq!(c.time, 100);
    }

    struct CtrlPing {
        peer: NodeId,
        got: bool,
        initiator: bool,
    }

    impl App for CtrlPing {
        fn on_start(&mut self, ctx: &mut AppCtx) {
            if self.initiator {
                ctx.send_ctrl(
                    self.peer,
                    CtrlMsg {
                        tag: 42,
                        payload: vec![1, 2, 3],
                    },
                );
            }
        }
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, _ev: CqEvent) {}
        fn on_wake(&mut self, _ctx: &mut AppCtx, _token: u64) {}
        fn on_ctrl(&mut self, ctx: &mut AppCtx, from: NodeId, msg: CtrlMsg) {
            assert_eq!(msg.tag, 42);
            assert_eq!(msg.payload, vec![1, 2, 3]);
            if !self.got {
                self.got = true;
                // echo back
                ctx.send_ctrl(from, msg);
            }
        }
        fn is_done(&self) -> bool {
            self.got
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    #[test]
    fn ctrl_channel_roundtrip() {
        let cfg = ClusterCfg::new(FabricCfg::cloudlab(2), TransportKind::Optinic);
        let mut c = Cluster::new(cfg);
        c.set_app(
            0,
            Box::new(CtrlPing {
                peer: 1,
                got: false,
                initiator: true,
            }),
        );
        c.set_app(
            1,
            Box::new(CtrlPing {
                peer: 0,
                got: false,
                initiator: false,
            }),
        );
        c.start_apps();
        assert!(c.run());
        assert!(c.time > 0);
    }

    #[test]
    fn connect_assigns_distinct_qpns_and_peers() {
        let cfg = ClusterCfg::new(FabricCfg::cloudlab(4), TransportKind::Optinic);
        let mut c = Cluster::new(cfg);
        let (a1, b1) = c.connect(0, 1, QpType::Xp);
        let (a2, b2) = c.connect(2, 3, QpType::Xp);
        assert_eq!(a1.peer, 1);
        assert_eq!(b1.peer, 0);
        assert_eq!(a2.peer, 3);
        assert_eq!(b2.peer, 2);
        let all = [a1.qpn, b1.qpn, a2.qpn, b2.qpn];
        for i in 0..4 {
            for j in i + 1..4 {
                assert_ne!(all[i], all[j]);
            }
        }
    }

    /// Two senders on distinct QPs, a receiver that posts NO per-QP recv
    /// WQEs — only SRQ entries. Both messages must complete as `RecvDone`
    /// events with complete loss maps, consuming exactly two SRQ entries.
    struct SrqSender {
        qp: QpHandle,
        mr: crate::verbs::MrId,
        fill: f32,
        done: bool,
    }

    impl App for SrqSender {
        fn on_start(&mut self, ctx: &mut AppCtx) {
            ctx.mem.write_f32(self.mr, 0, &vec![self.fill; 1024]);
            let wqe = Wqe::send(1, self.mr, 0, 4096).with_timeout(50_000_000);
            ctx.endpoint().post_send(self.qp, wqe);
        }
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
            if let CqEvent::SendDone { .. } | CqEvent::TimeoutFired { is_recv: false, .. } = ev
            {
                self.done = true;
            }
        }
        fn on_wake(&mut self, _ctx: &mut AppCtx, _t: u64) {}
        fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: CtrlMsg) {}
        fn is_done(&self) -> bool {
            self.done
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    struct SrqReceiver {
        mr: crate::verbs::MrId,
        got: usize,
        complete_maps: usize,
    }

    impl App for SrqReceiver {
        fn on_start(&mut self, ctx: &mut AppCtx) {
            // two shared entries, no per-QP recv WQEs at all
            let slots = vec![
                Wqe::recv(10, self.mr, 0, 4096).with_timeout(50_000_000),
                Wqe::recv(11, self.mr, 4096, 4096).with_timeout(50_000_000),
            ];
            let mut ep = ctx.endpoint();
            ep.post_srq_recv_batch(slots);
            assert_eq!(ep.srq_len(), 2);
        }
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
            if let CqEvent::RecvDone { loss_map, .. } = ev {
                self.got += 1;
                if loss_map.is_complete() {
                    self.complete_maps += 1;
                }
            }
        }
        fn on_wake(&mut self, _ctx: &mut AppCtx, _t: u64) {}
        fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: CtrlMsg) {}
        fn is_done(&self) -> bool {
            self.got >= 2
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    fn run_srq_feeds(transport: TransportKind) {
        let mut fab = FabricCfg::cloudlab(3);
        fab.corrupt_prob = 0.0; // lossless: loss maps must come back complete
        let cfg = ClusterCfg::new(fab, transport).with_seed(9);
        let mut c = Cluster::new(cfg);
        let dst = c.mem.register(0, 8192);
        let src1 = c.mem.register(1, 4096);
        let src2 = c.mem.register(2, 4096);
        let (s1, _r1) = c.connect(1, 0, QpType::Xp);
        let (s2, _r2) = c.connect(2, 0, QpType::Xp);
        c.set_app(
            0,
            Box::new(SrqReceiver {
                mr: dst,
                got: 0,
                complete_maps: 0,
            }),
        );
        c.set_app(
            1,
            Box::new(SrqSender {
                qp: s1,
                mr: src1,
                fill: 7.5,
                done: false,
            }),
        );
        c.set_app(
            2,
            Box::new(SrqSender {
                qp: s2,
                mr: src2,
                fill: 8.5,
                done: false,
            }),
        );
        c.start_apps();
        assert!(c.run(), "{transport:?}: SRQ run did not complete");
        assert_eq!(c.srq_consumed(0), 2, "{transport:?}: SRQ entries consumed");
        // both 4 KB messages landed (one per slot, arrival order unspecified)
        let data = c.mem.read_f32(dst, 0, 2048);
        let sevens = data.iter().filter(|&&v| v == 7.5).count();
        let eights = data.iter().filter(|&&v| v == 8.5).count();
        assert_eq!(sevens, 1024, "{transport:?}: sender-1 payload placed");
        assert_eq!(eights, 1024, "{transport:?}: sender-2 payload placed");
        let mut app = c.take_app(0).unwrap();
        let recv = app.as_any().downcast_mut::<SrqReceiver>().unwrap();
        assert_eq!(recv.complete_maps, 2, "{transport:?}: loss maps complete");
    }

    #[test]
    fn srq_feeds_multiple_qps_optinic() {
        run_srq_feeds(TransportKind::Optinic);
    }

    #[test]
    fn srq_feeds_multiple_qps_reliable() {
        run_srq_feeds(TransportKind::Irn);
    }

    /// Satellite regression (fails pre-fix): PFC was one global switch —
    /// any port above XOFF paused EVERY host's data class, so a hot port
    /// nobody talks to froze unrelated flows. Here port 1 is pinned above
    /// XOFF for the whole run (its drain is never scheduled) while an
    /// unrelated 2 → 3 transfer runs; per-port PFC lets it complete,
    /// global PFC blocked node 2's data class forever.
    #[test]
    fn pfc_idle_port_not_paused_by_unrelated_hot_port() {
        use crate::net::{DataHdr, NetHints};
        use crate::verbs::MrId;
        let mut fab = FabricCfg::cloudlab(4);
        fab.corrupt_prob = 0.0;
        let mut c = Cluster::new(ClusterCfg::new(fab, TransportKind::Roce).with_seed(3));
        // pin port 1 above XOFF: fill it directly, never kick its drain
        let mut rng = crate::util::prng::Pcg64::seeded(99);
        let hot = |len: usize| {
            Packet::data(
                0,
                1,
                DataHdr {
                    dst_qpn: 0,
                    src_qpn: 0,
                    psn: 0,
                    wqe_seq: 0,
                    msg_offset: 0,
                    len,
                    last: false,
                    msg_len: len,
                    src_mr: MrId(0),
                    src_off: 0,
                    reth: None,
                    stride: 1,
                    imm: None,
                    deadline: None,
                    tx_time: 0,
                    hints: NetHints::default(),
                },
            )
        };
        while c.fabric.queue_bytes(1) < c.cfg.fabric.pfc_xoff {
            assert!(matches!(
                c.fabric.enqueue(1, hot(4096), &mut rng),
                EnqueueOutcome::Queued { .. }
            ));
        }
        c.events.push(0, Event::PfcUpdate { link: 1 });
        // unrelated flow: 64 KB from node 2 to node 3 (idle port) — big
        // enough that the pause frames land mid-message
        let dst = c.mem.register(3, 64 * 1024);
        let src = c.mem.register(2, 64 * 1024);
        let (s, _r) = c.connect(2, 3, QpType::Xp);
        struct OneShotSender {
            qp: QpHandle,
            mr: crate::verbs::MrId,
            done: bool,
        }
        impl App for OneShotSender {
            fn on_start(&mut self, ctx: &mut AppCtx) {
                ctx.endpoint()
                    .post_send(self.qp, Wqe::send(1, self.mr, 0, 64 * 1024));
            }
            fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
                if matches!(ev, CqEvent::SendDone { .. }) {
                    self.done = true;
                }
            }
            fn on_wake(&mut self, _c: &mut AppCtx, _t: u64) {}
            fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: CtrlMsg) {}
            fn is_done(&self) -> bool {
                self.done
            }
            fn as_any(&mut self) -> &mut dyn std::any::Any {
                self
            }
        }
        struct OneShotReceiver {
            mr: crate::verbs::MrId,
            got: bool,
        }
        impl App for OneShotReceiver {
            fn on_start(&mut self, ctx: &mut AppCtx) {
                ctx.endpoint()
                    .post_srq_recv(Wqe::recv(10, self.mr, 0, 64 * 1024));
            }
            fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
                if matches!(ev, CqEvent::RecvDone { .. }) {
                    self.got = true;
                }
            }
            fn on_wake(&mut self, _c: &mut AppCtx, _t: u64) {}
            fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: CtrlMsg) {}
            fn is_done(&self) -> bool {
                self.got
            }
            fn as_any(&mut self) -> &mut dyn std::any::Any {
                self
            }
        }
        c.set_app(
            2,
            Box::new(OneShotSender {
                qp: s,
                mr: src,
                done: false,
            }),
        );
        c.set_app(3, Box::new(OneShotReceiver { mr: dst, got: false }));
        c.cfg.max_sim_time = 100 * crate::sim::MS;
        c.start_apps();
        assert!(
            c.run(),
            "idle-port flow must complete while an unrelated port is paused"
        );
        // the pause really happened — for port 1, at every host
        assert!(c.fabric.ports[1].pfc_asserted, "hot port must stay asserted");
        assert!(c.metrics.pfc_pause_events >= 4, "pause frames delivered");
    }

    /// Leaf–spine smoke: the SRQ contract holds across the multi-tier
    /// fabric (cross-leaf placement, both engine families).
    #[test]
    fn srq_feeds_over_leaf_spine() {
        for transport in [TransportKind::Optinic, TransportKind::Irn] {
            let mut fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
            fab.corrupt_prob = 0.0;
            let cfg = ClusterCfg::new(fab, transport).with_seed(9);
            let mut c = Cluster::new(cfg);
            let dst = c.mem.register(0, 8192);
            let src1 = c.mem.register(2, 4096); // cross-leaf sender
            let src2 = c.mem.register(3, 4096); // cross-leaf sender
            let (s1, _r1) = c.connect(2, 0, QpType::Xp);
            let (s2, _r2) = c.connect(3, 0, QpType::Xp);
            c.set_app(
                0,
                Box::new(SrqReceiver {
                    mr: dst,
                    got: 0,
                    complete_maps: 0,
                }),
            );
            c.set_app(
                2,
                Box::new(SrqSender {
                    qp: s1,
                    mr: src1,
                    fill: 7.5,
                    done: false,
                }),
            );
            c.set_app(
                3,
                Box::new(SrqSender {
                    qp: s2,
                    mr: src2,
                    fill: 8.5,
                    done: false,
                }),
            );
            c.start_apps();
            assert!(c.run(), "{transport:?}: leaf–spine SRQ run did not complete");
            let data = c.mem.read_f32(dst, 0, 2048);
            assert_eq!(data.iter().filter(|&&v| v == 7.5).count(), 1024);
            assert_eq!(data.iter().filter(|&&v| v == 8.5).count(), 1024);
            // traffic really crossed the core: spine ports forwarded bytes
            let core_tx: u64 = (c.nodes()..c.fabric.topo.n_links())
                .map(|l| c.fabric.ports[l].tx_bytes)
                .sum();
            assert!(core_tx > 0, "{transport:?}: no core-link traffic");
        }
    }

    /// Wholly-lost messages must not strand an SRQ-only receiver: entries
    /// whose queue-level deadline expires before any fragment arrives
    /// complete as `TimeoutFired` (here: no sender exists at all).
    struct SrqTimeoutApp {
        mr: crate::verbs::MrId,
        timeouts: usize,
        want: usize,
    }

    impl App for SrqTimeoutApp {
        fn on_start(&mut self, ctx: &mut AppCtx) {
            let slots: Vec<Wqe> = (0..self.want)
                .map(|i| {
                    Wqe::recv(i as u64, self.mr, i * 1024, 1024)
                        .with_timeout(1_000_000 * (i as u64 + 1))
                })
                .collect();
            ctx.endpoint().post_srq_recv_batch(slots);
        }
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, ev: CqEvent) {
            if let CqEvent::TimeoutFired {
                is_recv: true,
                delivered_bytes: 0,
                expected_bytes: 1024,
                ..
            } = ev
            {
                self.timeouts += 1;
            }
        }
        fn on_wake(&mut self, _ctx: &mut AppCtx, _t: u64) {}
        fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: CtrlMsg) {}
        fn is_done(&self) -> bool {
            self.timeouts >= self.want
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    #[test]
    fn srq_entries_time_out_when_wholly_lost() {
        let cfg = ClusterCfg::new(FabricCfg::cloudlab(2), TransportKind::Optinic);
        let mut c = Cluster::new(cfg);
        let mr = c.mem.register(0, 2048);
        c.set_app(
            0,
            Box::new(SrqTimeoutApp {
                mr,
                timeouts: 0,
                want: 2,
            }),
        );
        c.start_apps();
        assert!(c.run(), "SRQ-only receiver must not hang on total loss");
        assert_eq!(c.time, 2_000_000, "second entry's deadline gates completion");
        assert_eq!(c.srq_consumed(0), 0, "nothing ever consumed the entries");
    }

    /// Wheel and heap backends must drive the engine through bit-identical
    /// trajectories (the full-stack parity suite lives in
    /// `rust/tests/determinism.rs`).
    #[test]
    fn scheduler_parity_smoke() {
        let run = |sched: SchedKind| {
            let cfg = ClusterCfg::new(FabricCfg::cloudlab(4), TransportKind::Optinic)
                .with_seed(7)
                .with_bg_load(0.4)
                .with_scheduler(sched);
            let mut c = Cluster::new(cfg);
            c.set_app(0, Box::new(NullApp { done: false }));
            c.cfg.max_sim_time = 500_000;
            c.start_apps();
            c.run();
            c.run_until(400_000);
            (
                c.time,
                c.events_processed,
                c.metrics.pkts_dropped_queue,
                c.metrics.tx_trains,
                c.metrics.tx_train_pkts,
            )
        };
        assert_eq!(run(SchedKind::Wheel), run(SchedKind::Heap));
    }

    /// Same parity contract over the multi-tier fabric: per-hop queues,
    /// ECMP, spraying, and bg traffic must be scheduler-invariant too.
    #[test]
    fn scheduler_parity_smoke_leaf_spine() {
        let run = |sched: SchedKind| {
            let fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
            let cfg = ClusterCfg::new(fab, TransportKind::Optinic)
                .with_seed(7)
                .with_bg_load(0.4)
                .with_scheduler(sched);
            let mut c = Cluster::new(cfg);
            c.set_app(0, Box::new(NullApp { done: false }));
            c.cfg.max_sim_time = 500_000;
            c.start_apps();
            c.run();
            c.run_until(400_000);
            (
                c.time,
                c.events_processed,
                c.metrics.pkts_dropped_queue,
                c.metrics.tx_trains,
                c.metrics.tx_train_pkts,
            )
        };
        assert_eq!(run(SchedKind::Wheel), run(SchedKind::Heap));
    }

    #[test]
    fn deterministic_event_counts() {
        let run = |seed| {
            let cfg = ClusterCfg::new(FabricCfg::cloudlab(4), TransportKind::Optinic)
                .with_seed(seed)
                .with_bg_load(0.3);
            let mut c = Cluster::new(cfg);
            c.set_app(0, Box::new(NullApp { done: false }));
            // run some bg traffic alongside
            c.cfg.max_sim_time = 200_000;
            c.start_apps();
            c.run();
            (c.events_processed, c.metrics.pkts_dropped_queue)
        };
        assert_eq!(run(7), run(7));
    }

    /// Cross-partition SRQ transfer under the partitioned engine: run the
    /// leaf–spine SRQ scenario (both senders on the OTHER leaf, so every
    /// data fragment crosses a partition boundary and rides an envelope
    /// payload refresh) at several worker counts and demand byte-identical
    /// merged metrics, time, event counts, AND placed payload bytes.
    fn run_partitioned_srq(cores: usize) -> (String, SimTime, u64, Vec<f32>) {
        let mut fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
        fab.corrupt_prob = 0.0;
        let cfg = ClusterCfg::new(fab, TransportKind::Optinic)
            .with_seed(9)
            .with_cores(cores);
        let mut c = Cluster::new(cfg);
        let dst = c.mem.register(0, 8192);
        let src1 = c.mem.register(2, 4096);
        let src2 = c.mem.register(3, 4096);
        let (s1, _r1) = c.connect(2, 0, QpType::Xp);
        let (s2, _r2) = c.connect(3, 0, QpType::Xp);
        c.set_app(
            0,
            Box::new(SrqReceiver {
                mr: dst,
                got: 0,
                complete_maps: 0,
            }),
        );
        c.set_app(
            2,
            Box::new(SrqSender {
                qp: s1,
                mr: src1,
                fill: 7.5,
                done: false,
            }),
        );
        c.set_app(
            3,
            Box::new(SrqSender {
                qp: s2,
                mr: src2,
                fill: 8.5,
                done: false,
            }),
        );
        c.start_apps();
        assert!(c.run(), "partitioned SRQ run (cores={cores}) did not complete");
        let data = c.mem.read_f32(dst, 0, 2048);
        assert_eq!(data.iter().filter(|&&v| v == 7.5).count(), 1024);
        assert_eq!(data.iter().filter(|&&v| v == 8.5).count(), 1024);
        (
            c.metrics.to_json().to_string_compact(),
            c.time,
            c.events_processed,
            data,
        )
    }

    #[test]
    fn partitioned_srq_byte_identical_across_core_counts() {
        let one = run_partitioned_srq(1);
        assert_eq!(one, run_partitioned_srq(2));
        assert_eq!(one, run_partitioned_srq(4));
    }

    /// The ctrl channel crosses partitions too (envelopes without payload
    /// refresh) — and the run must also complete with more workers than
    /// partitions.
    #[test]
    fn partitioned_ctrl_roundtrip_across_partitions() {
        for cores in [1, 2, 8] {
            let fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
            let cfg = ClusterCfg::new(fab, TransportKind::Optinic).with_cores(cores);
            let mut c = Cluster::new(cfg);
            // node 0 lives on leaf 0, node 3 on leaf 1: the ping crosses
            c.set_app(
                0,
                Box::new(CtrlPing {
                    peer: 3,
                    got: false,
                    initiator: true,
                }),
            );
            c.set_app(
                3,
                Box::new(CtrlPing {
                    peer: 0,
                    got: false,
                    initiator: false,
                }),
            );
            c.start_apps();
            assert!(c.run(), "ctrl roundtrip (cores={cores}) did not complete");
            assert!(c.time > 0);
        }
    }

    /// A single-switch topology has one partition: `--cores` must quietly
    /// fall back to the legacy loop and still finish.
    #[test]
    fn partitioned_single_switch_falls_back_to_legacy() {
        let cfg = ClusterCfg::new(FabricCfg::cloudlab(2), TransportKind::Optinic).with_cores(4);
        let mut c = Cluster::new(cfg);
        c.set_app(0, Box::new(NullApp { done: false }));
        c.set_app(1, Box::new(NullApp { done: false }));
        c.start_apps();
        assert!(c.run());
        assert_eq!(c.time, 100);
    }

    /// The simulation wall aborts a partitioned run the same way the
    /// legacy loop does: `run` returns false, identically for any core
    /// count.
    #[test]
    fn partitioned_wall_abort_is_core_count_invariant() {
        let run = |cores: usize| {
            let fab = FabricCfg::cloudlab(4).with_leaf_spine(2, 2);
            let cfg = ClusterCfg::new(fab, TransportKind::Optinic)
                .with_seed(5)
                .with_bg_load(0.5)
                .with_cores(cores);
            let mut c = Cluster::new(cfg);
            c.set_app(0, Box::new(NeverDone)); // keeps the run alive
            c.cfg.max_sim_time = 300_000;
            c.start_apps();
            let done = c.run();
            (done, c.time, c.events_processed)
        };
        let one = run(1);
        assert!(!one.0, "wall must abort the run");
        assert_eq!(one, run(2));
    }

    struct NeverDone;

    impl App for NeverDone {
        fn on_start(&mut self, _ctx: &mut AppCtx) {}
        fn on_cq_event(&mut self, _ctx: &mut AppCtx, _ev: CqEvent) {}
        fn on_wake(&mut self, _ctx: &mut AppCtx, _t: u64) {}
        fn on_ctrl(&mut self, _c: &mut AppCtx, _f: NodeId, _m: CtrlMsg) {}
        fn is_done(&self) -> bool {
            false
        }
        fn as_any(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    /// Freelists actually recycle on the hot path.
    #[test]
    fn pools_recycle_buffers() {
        let mut fab = FabricCfg::cloudlab(3);
        fab.corrupt_prob = 0.0;
        let cfg = ClusterCfg::new(fab, TransportKind::Optinic).with_seed(9);
        let mut c = Cluster::new(cfg);
        let dst = c.mem.register(0, 8192);
        let src1 = c.mem.register(1, 4096);
        let src2 = c.mem.register(2, 4096);
        let (s1, _r1) = c.connect(1, 0, QpType::Xp);
        let (s2, _r2) = c.connect(2, 0, QpType::Xp);
        c.set_app(
            0,
            Box::new(SrqReceiver {
                mr: dst,
                got: 0,
                complete_maps: 0,
            }),
        );
        c.set_app(
            1,
            Box::new(SrqSender {
                qp: s1,
                mr: src1,
                fill: 7.5,
                done: false,
            }),
        );
        c.set_app(
            2,
            Box::new(SrqSender {
                qp: s2,
                mr: src2,
                fill: 8.5,
                done: false,
            }),
        );
        c.start_apps();
        assert!(c.run());
        assert!(
            c.metrics.pool_recycles > 0,
            "multi-packet transfers must feed the train freelist"
        );
    }
}
