//! Event scheduling: the deterministic min-(time, seq) queue behind the
//! whole simulator.
//!
//! Two interchangeable backends live behind [`EventQueue`]:
//!
//! * [`SchedKind::Wheel`] (default) — a hierarchical timing wheel
//!   (calendar queue): 8 levels of 256 slots, level `L` spanning
//!   `256^L` ns per slot, so the full `u64` time axis is covered with no
//!   overflow list. `push` is O(1) (index by the highest differing byte
//!   between the event time and the current time); `pop` amortizes to
//!   O(1) via per-level occupancy bitmaps (find-next-slot is a couple of
//!   `trailing_zeros`) plus one cascade per slot per level over the
//!   event's lifetime. This is the classic fix for DES event churn:
//!   timer re-arms and per-packet events stop paying `O(log n)` heap
//!   sifts against hundreds of thousands of in-flight entries.
//! * [`SchedKind::Heap`] — the original `BinaryHeap` implementation,
//!   kept as a reference scheduler selectable through
//!   `ClusterCfg::scheduler` for A/B parity testing.
//!
//! Determinism contract (both backends, bit-identical to each other):
//! events pop ordered by `(time, insertion seq)` — FIFO among ties. The
//! wheel preserves it exactly: a drained level-0 slot holds exactly one
//! timestamp (all higher time bytes are pinned by the slot's position),
//! so sorting the slot by `seq` reproduces the heap order; pushes at the
//! current time append to the staging row in `seq` order.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::sim::SimTime;

/// Scheduler backend selector (`ClusterCfg::scheduler`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchedKind {
    /// Hierarchical timing wheel (default).
    Wheel,
    /// Reference `BinaryHeap` scheduler (A/B parity baseline).
    Heap,
}

impl SchedKind {
    pub fn name(&self) -> &'static str {
        match self {
            SchedKind::Wheel => "wheel",
            SchedKind::Heap => "heap",
        }
    }
}

/// Cross-partition-stable tie-break key: `(origin partition, per-origin
/// insertion seq)`. A single-threaded queue uses origin 0 and its own
/// monotone seq (the classic FIFO tie-break); the partitioned engine
/// stamps events with the partition that *scheduled* them so that
/// same-time events from different partitions order identically no
/// matter how many worker threads ran the simulation.
pub type EventKey = (u32, u64);

#[derive(Debug)]
struct Entry<E> {
    time: SimTime,
    key: EventKey,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.key == other.key
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.key).cmp(&(other.time, other.key))
    }
}

const WHEEL_LEVELS: usize = 8;
const WHEEL_SLOTS: usize = 256; // level L slot width = 256^L ns
const OCC_WORDS: usize = WHEEL_SLOTS / 64;

/// Hierarchical timing wheel over the full `u64` nanosecond axis.
#[derive(Debug)]
struct TimingWheel<E> {
    /// Flattened `[level][slot]` buckets (capacities recycled in place).
    slots: Vec<Vec<Entry<E>>>,
    /// Per-level occupancy bitmaps: bit `s` of level `l` ⇔ slot non-empty.
    occ: [[u64; OCC_WORDS]; WHEEL_LEVELS],
    /// Time of the most recently popped event (events at exactly this
    /// time go straight to `ready`; everything else is strictly later).
    cur: SimTime,
    /// The drained current-timestamp slot, in pop order.
    ready: VecDeque<Entry<E>>,
    len: usize,
}

impl<E> TimingWheel<E> {
    fn new() -> Self {
        TimingWheel {
            slots: (0..WHEEL_LEVELS * WHEEL_SLOTS).map(|_| Vec::new()).collect(),
            occ: [[0; OCC_WORDS]; WHEEL_LEVELS],
            cur: 0,
            ready: VecDeque::new(),
            len: 0,
        }
    }

    #[inline]
    fn byte_of(t: SimTime, level: usize) -> usize {
        ((t >> (8 * level)) & 0xff) as usize
    }

    #[inline]
    fn set_occ(&mut self, level: usize, slot: usize) {
        self.occ[level][slot >> 6] |= 1u64 << (slot & 63);
    }

    #[inline]
    fn clear_occ(&mut self, level: usize, slot: usize) {
        self.occ[level][slot >> 6] &= !(1u64 << (slot & 63));
    }

    /// Smallest occupied slot index `>= lo` at `level`, if any.
    fn next_occ(&self, level: usize, lo: usize) -> Option<usize> {
        if lo >= WHEEL_SLOTS {
            return None;
        }
        let word = lo >> 6;
        let bits = self.occ[level][word] >> (lo & 63);
        if bits != 0 {
            return Some(lo + bits.trailing_zeros() as usize);
        }
        for w in word + 1..OCC_WORDS {
            let b = self.occ[level][w];
            if b != 0 {
                return Some((w << 6) + b.trailing_zeros() as usize);
            }
        }
        None
    }

    /// File a future event: its level is the highest byte in which its
    /// time differs from `cur`, its slot that byte's value.
    fn insert(&mut self, e: Entry<E>) {
        debug_assert!(e.time > self.cur);
        let diff = e.time ^ self.cur;
        let level = ((63 - diff.leading_zeros()) >> 3) as usize;
        let slot = Self::byte_of(e.time, level);
        self.slots[level * WHEEL_SLOTS + slot].push(e);
        self.set_occ(level, slot);
    }

    fn push(&mut self, time: SimTime, key: EventKey, ev: E) {
        self.len += 1;
        if time <= self.cur {
            // The engine never schedules into the past (it debug-asserts
            // time monotonicity); at-current-time events join the staging
            // row in key order — the heap's exact tie-break. Single-origin
            // pushes carry a strictly increasing key so the scan is O(1)
            // (pure append); only a partitioned shard that pushes while
            // same-time envelope entries from a higher-numbered origin are
            // still staged ever walks backwards.
            debug_assert!(time == self.cur, "event scheduled in the past");
            let mut i = self.ready.len();
            while i > 0 && self.ready[i - 1].key > key {
                i -= 1;
            }
            self.ready.insert(
                i,
                Entry {
                    time: self.cur,
                    key,
                    ev,
                },
            );
        } else {
            self.insert(Entry { time, key, ev });
        }
    }

    /// Advance to the next occupied slot and stage its events in `ready`,
    /// cascading higher-level slots down as needed. A drained level-0
    /// slot holds exactly one timestamp; sorting it by `seq` restores the
    /// global (time, seq) order even for entries that cascaded down from
    /// different levels.
    fn ensure_ready(&mut self) {
        if !self.ready.is_empty() || self.len == 0 {
            return;
        }
        let mut lo = [0usize; WHEEL_LEVELS];
        for (level, l) in lo.iter_mut().enumerate() {
            *l = Self::byte_of(self.cur, level) + 1;
        }
        loop {
            if let Some(slot) = self.next_occ(0, lo[0]) {
                let mut v = std::mem::take(&mut self.slots[slot]);
                self.clear_occ(0, slot);
                v.sort_unstable_by_key(|e| e.key);
                self.cur = v[0].time;
                debug_assert!(v.iter().all(|e| e.time == self.cur));
                self.ready.extend(v.drain(..));
                self.slots[slot] = v; // recycle capacity
                return;
            }
            let mut cascaded = false;
            for level in 1..WHEEL_LEVELS {
                let Some(slot) = self.next_occ(level, lo[level]) else {
                    continue;
                };
                let flat = level * WHEEL_SLOTS + slot;
                let mut v = std::mem::take(&mut self.slots[flat]);
                self.clear_occ(level, slot);
                for e in v.drain(..) {
                    // redistribute below `level`: relative to the slot
                    // window's start (whose lower bytes are all zero) the
                    // entry's level is its highest non-zero lower byte
                    let mut l = 0;
                    for k in (0..level).rev() {
                        if Self::byte_of(e.time, k) != 0 {
                            l = k;
                            break;
                        }
                    }
                    let s = Self::byte_of(e.time, l);
                    self.slots[l * WHEEL_SLOTS + s].push(e);
                    self.set_occ(l, s);
                }
                self.slots[flat] = v;
                for x in lo.iter_mut().take(level) {
                    *x = 0;
                }
                cascaded = true;
                break;
            }
            if !cascaded {
                debug_assert!(false, "timing wheel lost {} events", self.len);
                return;
            }
        }
    }

    fn pop(&mut self) -> Option<Entry<E>> {
        self.ensure_ready();
        let e = self.ready.pop_front()?;
        self.len -= 1;
        Some(e)
    }

    /// Next event time WITHOUT mutating the wheel. Advancing here would
    /// move `cur` past times the engine may still schedule at (e.g.
    /// `run_until` peeks beyond its horizon, then the caller keeps
    /// pushing at the current sim time), so peek derives the minimum
    /// structurally instead: levels are strictly time-ordered (a level-L
    /// entry differs from `cur` first at byte L, above every lower-level
    /// window), slots within a level are ordered by index, a level-0
    /// slot holds exactly one timestamp, and only a higher-level slot
    /// needs a min-scan over its (unsorted) entries.
    fn peek_time(&self) -> Option<SimTime> {
        if let Some(e) = self.ready.front() {
            return Some(e.time);
        }
        if self.len == 0 {
            return None;
        }
        if let Some(slot) = self.next_occ(0, Self::byte_of(self.cur, 0) + 1) {
            return Some(self.slots[slot][0].time);
        }
        for level in 1..WHEEL_LEVELS {
            let lo = Self::byte_of(self.cur, level) + 1;
            if let Some(slot) = self.next_occ(level, lo) {
                return self.slots[level * WHEEL_SLOTS + slot]
                    .iter()
                    .map(|e| e.time)
                    .min();
            }
        }
        debug_assert!(false, "timing wheel lost {} events", self.len);
        None
    }

    fn clear(&mut self) {
        for v in &mut self.slots {
            v.clear();
        }
        self.occ = [[0; OCC_WORDS]; WHEEL_LEVELS];
        self.ready.clear();
        self.len = 0;
        // a cleared queue must accept pushes at any time again
        self.cur = 0;
    }
}

#[derive(Debug)]
enum QueueImpl<E> {
    Heap(BinaryHeap<Reverse<Entry<E>>>),
    Wheel(TimingWheel<E>),
}

/// Deterministic event queue: min-(time, seq) with FIFO tie-break.
/// Defaults to the timing wheel; the heap stays selectable for parity.
#[derive(Debug)]
pub struct EventQueue<E> {
    imp: QueueImpl<E>,
    seq: u64,
    pub scheduled: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::with_kind(SchedKind::Wheel)
    }

    pub fn with_kind(kind: SchedKind) -> Self {
        let imp = match kind {
            SchedKind::Heap => QueueImpl::Heap(BinaryHeap::new()),
            SchedKind::Wheel => QueueImpl::Wheel(TimingWheel::new()),
        };
        EventQueue {
            imp,
            seq: 0,
            scheduled: 0,
        }
    }

    pub fn kind(&self) -> SchedKind {
        match &self.imp {
            QueueImpl::Heap(_) => SchedKind::Heap,
            QueueImpl::Wheel(_) => SchedKind::Wheel,
        }
    }

    pub fn push(&mut self, time: SimTime, ev: E) {
        self.seq += 1;
        let key = (0u32, self.seq);
        self.push_keyed(time, key, ev);
    }

    /// Push with an explicit `(origin, seq)` tie-break key. The partitioned
    /// engine assigns keys itself (per-origin counters) so that merged
    /// event order is independent of worker-thread count; the key must be
    /// unique per queue and, for at-current-time pushes, strictly
    /// increasing per origin.
    pub fn push_keyed(&mut self, time: SimTime, key: EventKey, ev: E) {
        self.scheduled += 1;
        match &mut self.imp {
            QueueImpl::Heap(h) => h.push(Reverse(Entry { time, key, ev })),
            QueueImpl::Wheel(w) => w.push(time, key, ev),
        }
    }

    /// The internal single-origin insertion counter (the `seq` half of the
    /// keys minted by [`EventQueue::push`]). The partitioned engine reads
    /// it when splitting a root queue so shard-local counters continue
    /// strictly above every setup event's key.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        match &mut self.imp {
            QueueImpl::Heap(h) => h.pop().map(|Reverse(e)| (e.time, e.ev)),
            QueueImpl::Wheel(w) => w.pop().map(|e| (e.time, e.ev)),
        }
    }

    /// Drain every pending entry in `(time, key)` order, keys included.
    /// Used once when the partitioned engine splits a fully set-up root
    /// queue across shards (setup events keep their original keys so they
    /// still order ahead of same-time runtime events).
    pub fn drain(&mut self) -> Vec<(SimTime, EventKey, E)> {
        let mut out = Vec::with_capacity(self.len());
        match &mut self.imp {
            QueueImpl::Heap(h) => {
                while let Some(Reverse(e)) = h.pop() {
                    out.push((e.time, e.key, e.ev));
                }
            }
            QueueImpl::Wheel(w) => {
                while let Some(e) = w.pop() {
                    out.push((e.time, e.key, e.ev));
                }
            }
        }
        out
    }

    /// Next event time without consuming (or mutating) the queue.
    pub fn peek_time(&self) -> Option<SimTime> {
        match &self.imp {
            QueueImpl::Heap(h) => h.peek().map(|Reverse(e)| e.time),
            QueueImpl::Wheel(w) => w.peek_time(),
        }
    }

    pub fn len(&self) -> usize {
        match &self.imp {
            QueueImpl::Heap(h) => h.len(),
            QueueImpl::Wheel(w) => w.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&mut self) {
        match &mut self.imp {
            QueueImpl::Heap(h) => h.clear(),
            QueueImpl::Wheel(w) => w.clear(),
        }
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BOTH: [SchedKind; 2] = [SchedKind::Wheel, SchedKind::Heap];

    #[test]
    fn pops_in_time_order() {
        for kind in BOTH {
            let mut q = EventQueue::with_kind(kind);
            q.push(30, "c");
            q.push(10, "a");
            q.push(20, "b");
            assert_eq!(q.pop(), Some((10, "a")), "{kind:?}");
            assert_eq!(q.pop(), Some((20, "b")), "{kind:?}");
            assert_eq!(q.pop(), Some((30, "c")), "{kind:?}");
            assert_eq!(q.pop(), None, "{kind:?}");
        }
    }

    #[test]
    fn ties_break_fifo() {
        for kind in BOTH {
            let mut q = EventQueue::with_kind(kind);
            q.push(5, 1);
            q.push(5, 2);
            q.push(5, 3);
            assert_eq!(q.pop().unwrap().1, 1, "{kind:?}");
            assert_eq!(q.pop().unwrap().1, 2, "{kind:?}");
            assert_eq!(q.pop().unwrap().1, 3, "{kind:?}");
        }
    }

    #[test]
    fn peek_and_len() {
        for kind in BOTH {
            let mut q = EventQueue::with_kind(kind);
            assert!(q.is_empty(), "{kind:?}");
            q.push(7, ());
            assert_eq!(q.peek_time(), Some(7), "{kind:?}");
            assert_eq!(q.len(), 1, "{kind:?}");
        }
    }

    #[test]
    fn interleaved_push_pop_stays_sorted() {
        for kind in BOTH {
            let mut q = EventQueue::with_kind(kind);
            q.push(10, 10u64);
            q.push(5, 5);
            assert_eq!(q.pop(), Some((5, 5)), "{kind:?}");
            q.push(6, 6);
            q.push(20, 20);
            assert_eq!(q.pop(), Some((6, 6)), "{kind:?}");
            assert_eq!(q.pop(), Some((10, 10)), "{kind:?}");
            assert_eq!(q.pop(), Some((20, 20)), "{kind:?}");
        }
    }

    #[test]
    fn wheel_crosses_level_boundaries() {
        let mut q = EventQueue::with_kind(SchedKind::Wheel);
        // straddle byte boundaries at every level, plus same-slot ties
        let times = [
            0u64,
            1,
            255,
            256,
            257,
            65_535,
            65_536,
            65_537,
            1 << 24,
            (1 << 24) + 3,
            (1 << 32) + 9,
            (1 << 40) + 1,
            (1 << 56) + 123,
            u64::MAX / 2,
        ];
        for (i, &t) in times.iter().enumerate() {
            q.push(t, i);
        }
        let mut sorted: Vec<(u64, usize)> =
            times.iter().copied().zip(0..times.len()).collect();
        sorted.sort();
        for (t, i) in sorted {
            assert_eq!(q.pop(), Some((t, i)));
        }
        assert_eq!(q.pop(), None);
    }

    /// Peeking must not perturb the wheel: peek far beyond the current
    /// time, then push EARLIER events (still >= the last popped time) —
    /// the `run_until`-then-keep-scheduling pattern — and pops must stay
    /// heap-ordered.
    #[test]
    fn peek_is_pure_under_late_earlier_pushes() {
        let mut q = EventQueue::with_kind(SchedKind::Wheel);
        q.push(10, 1u64);
        assert_eq!(q.pop(), Some((10, 1)));
        q.push(1_000_000, 2); // far future
        assert_eq!(q.peek_time(), Some(1_000_000));
        // now schedule earlier work at/after the current time (10)
        q.push(10, 3);
        q.push(500, 4);
        assert_eq!(q.peek_time(), Some(10));
        assert_eq!(q.pop(), Some((10, 3)));
        assert_eq!(q.pop(), Some((500, 4)));
        assert_eq!(q.pop(), Some((1_000_000, 2)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn clear_resets_for_reuse() {
        let mut q = EventQueue::with_kind(SchedKind::Wheel);
        q.push(1 << 30, 1u64);
        assert_eq!(q.pop(), Some((1 << 30, 1)));
        q.push((1 << 30) + 5, 2);
        q.clear();
        assert!(q.is_empty());
        // a fresh simulation may start from time 0 again
        q.push(3, 7);
        q.push(1, 9);
        assert_eq!(q.pop(), Some((1, 9)));
        assert_eq!(q.pop(), Some((3, 7)));
    }

    #[test]
    fn keyed_pushes_order_by_origin_then_seq() {
        for kind in BOTH {
            let mut q = EventQueue::with_kind(kind);
            q.push_keyed(5, (1, 7), "b");
            q.push_keyed(5, (0, 9), "a");
            q.push_keyed(3, (2, 1), "first");
            q.push_keyed(5, (1, 8), "c");
            assert_eq!(q.pop(), Some((3, "first")), "{kind:?}");
            assert_eq!(q.pop(), Some((5, "a")), "{kind:?}");
            assert_eq!(q.pop(), Some((5, "b")), "{kind:?}");
            assert_eq!(q.pop(), Some((5, "c")), "{kind:?}");
        }
    }

    /// A shard pushing at the current time while same-time entries from a
    /// higher-numbered origin are already staged must still pop in global
    /// (time, key) order — the wheel's staging row does a sorted insert.
    #[test]
    fn same_time_keyed_push_lands_before_staged_higher_origin() {
        for kind in BOTH {
            let mut q = EventQueue::with_kind(kind);
            q.push_keyed(10, (2, 1), "x");
            q.push_keyed(10, (3, 1), "z");
            assert_eq!(q.pop(), Some((10, "x")), "{kind:?}");
            // handler of "x" (origin 2) schedules zero-delay work
            q.push_keyed(10, (2, 2), "y");
            assert_eq!(q.pop(), Some((10, "y")), "{kind:?}");
            assert_eq!(q.pop(), Some((10, "z")), "{kind:?}");
        }
    }

    #[test]
    fn drain_returns_time_key_order_with_keys() {
        for kind in BOTH {
            let mut q = EventQueue::with_kind(kind);
            q.push(20, "late"); // key (0, 1)
            q.push_keyed(10, (4, 2), "mid");
            q.push_keyed(10, (1, 5), "early");
            let drained = q.drain();
            assert!(q.is_empty(), "{kind:?}");
            assert_eq!(
                drained,
                vec![
                    (10, (1, 5), "early"),
                    (10, (4, 2), "mid"),
                    (20, (0, 1), "late"),
                ],
                "{kind:?}"
            );
        }
    }

    /// The load-bearing guarantee: the wheel is bit-identical to the
    /// reference heap over randomized push/pop/peek interleavings that
    /// mimic the engine (batched pushes at the just-popped time, delays
    /// from 0 ns to ~2^45 ns).
    #[test]
    fn wheel_matches_heap_randomized() {
        use crate::util::prng::Pcg64;
        for seed in 0..8u64 {
            let mut rng = Pcg64::seeded(seed);
            let mut w = EventQueue::with_kind(SchedKind::Wheel);
            let mut h = EventQueue::with_kind(SchedKind::Heap);
            let mut now = 0u64;
            let mut next_ev = 0u64;
            let mut popped = 0usize;
            while popped < 4000 {
                for _ in 0..rng.below(4) {
                    let delay = match rng.below(5) {
                        0 => 0,
                        1 => 1 + rng.below(300),
                        2 => 300 + rng.below(70_000),
                        3 => 70_000 + rng.below(1 << 25),
                        _ => rng.below(1 << 45),
                    };
                    next_ev += 1;
                    w.push(now + delay, next_ev);
                    h.push(now + delay, next_ev);
                }
                if w.is_empty() {
                    next_ev += 1;
                    let delay = rng.below(100);
                    w.push(now + delay, next_ev);
                    h.push(now + delay, next_ev);
                }
                if rng.below(3) == 0 {
                    assert_eq!(w.peek_time(), h.peek_time(), "seed {seed}");
                }
                let a = w.pop();
                let b = h.pop();
                assert_eq!(a, b, "seed {seed} after {popped} pops");
                now = a.unwrap().0;
                popped += 1;
            }
            // drain to empty in lockstep
            loop {
                let (a, b) = (w.pop(), h.pop());
                assert_eq!(a, b, "seed {seed} drain");
                if a.is_none() {
                    break;
                }
            }
            assert!(w.is_empty() && h.is_empty());
        }
    }
}
