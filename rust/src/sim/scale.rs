//! Cluster-scale collective runner over the hybrid fidelity engine.
//!
//! The full packet DES ([`crate::sim::cluster`]) tops out around a few
//! hundred ranks per affordable figure cell; this runner drives the same
//! pure-data collective schedules ([`crate::collectives::schedule`])
//! through [`FlowSim`] instead, so 1k-rank fat-tree cells finish in
//! seconds. Per rank it keeps a step cursor: a step issues its send as a
//! FlowSim flow at the instant the previous step finished, and completes
//! when both its send flow and its matching arrival (the peer's send)
//! have finished — exactly the blocking-step execution model the
//! symbolic schedule harness and the packet engine use, so schedules
//! need no translation.
//!
//! Tail variance comes from deterministic re-rolls: iteration `i` XORs a
//! seed-derived salt into every ECMP label ([`FlowSim::ecmp_salt`]), so
//! hash-pinned transports (RoCE-style) see different collision patterns
//! per iteration while sprayed transports stay balanced — the
//! OptiNIC-vs-RoCE tail contrast at scale. Everything is replayable bit
//! for bit: same cell, same seed, same result, on either event-queue
//! backend (pinned in `rust/tests/determinism.rs`).

use std::collections::{HashMap, VecDeque};

use crate::cc::{CcKind, CC_ENDPOINT_BYTES};
use crate::collectives::schedule::{hier_allreduce, CollectiveKind, Step};
use crate::net::topo::NetFault;
use crate::net::{FabricCfg, FidelityMode, FidelityPolicy, Flow, FlowId, FlowSim, FluidLink};
use crate::sim::{SchedKind, SimTime};

/// One point of the scale sweep grid.
#[derive(Clone, Debug)]
pub struct ScaleCell {
    pub fabric: FabricCfg,
    pub kind: CollectiveKind,
    /// Use the topology-aware hierarchical AllReduce (rack size =
    /// `hosts_per_leaf`) instead of the flat schedule.
    pub hier: bool,
    pub fidelity: FidelityMode,
    /// Per-packet spraying (OptiNIC-style) vs hash-pinned ECMP (RoCE-style).
    pub spray: bool,
    /// f32 elements per rank buffer.
    pub elems: usize,
    pub iters: usize,
    pub seed: u64,
    pub sched: SchedKind,
    /// Couple every iteration's fluid plane to this congestion-control
    /// policy through the shared `RateAuthority` seam (`None` =
    /// uncapped fair-share rates, the pre-coupling behavior).
    pub cc: Option<CcKind>,
    /// Link faults injected into every iteration (same `NetFault`
    /// vocabulary as the packet engine).
    pub faults: Vec<(SimTime, NetFault)>,
    /// Worker threads for this cell. The scale runner partitions by
    /// *iteration* — each iteration is a fully independent `FlowSim`
    /// with a deterministic per-iteration ECMP salt — and additionally
    /// builds the per-rank schedules in parallel at 4096+ ranks.
    /// Results merge in fixed iteration order, so the `ScaleResult` is
    /// byte-identical for any value (`None` = serial). Same contract as
    /// the packet engine's `ClusterCfg::with_cores`.
    pub cores: Option<usize>,
}

impl ScaleCell {
    pub fn new(fabric: FabricCfg, kind: CollectiveKind, elems: usize) -> ScaleCell {
        ScaleCell {
            fabric,
            kind,
            hier: false,
            fidelity: FidelityMode::Hybrid,
            spray: false,
            elems,
            iters: 2,
            seed: 42,
            sched: SchedKind::Wheel,
            cc: None,
            faults: Vec::new(),
            cores: None,
        }
    }

    /// Wall-clock-only parallelism knob; see the `cores` field docs.
    pub fn with_cores(mut self, cores: usize) -> ScaleCell {
        self.cores = Some(cores);
        self
    }

    /// CC-couple the fluid plane; see the `cc` field docs.
    pub fn with_cc(mut self, cc: CcKind) -> ScaleCell {
        self.cc = Some(cc);
        self
    }

    /// Rough resident-set estimate for this cell while it runs,
    /// mirroring `CollectiveCell::est_cluster_bytes` on the packet
    /// side: the memory-bounded sweep planner needs fluid-engine state
    /// charged too. Covers the flyweight flow table, the fluid link
    /// table (fabric links + virtual NIC uplinks), and — when the CC
    /// plane is on — its per-flow/per-link side columns plus live
    /// endpoint CC state (endpoints retire at flow completion, so only
    /// in-flight sends hold one: ≤ 2 per rank under the blocking-step
    /// model). Scaled by how many iterations run concurrently.
    pub fn est_cluster_bytes(&self) -> usize {
        let topo = self.fabric.topology();
        let n = self.fabric.nodes;
        let n_links = topo.n_links() + n; // + virtual NIC uplinks
        let hpl = topo.hosts_per_leaf.max(1);
        let steps = if self.hier {
            2 * (hpl - 1) + 2 * n.div_ceil(hpl).saturating_sub(1) + 2
        } else {
            2 * n.saturating_sub(1)
        };
        let flows = n * steps.max(1);
        let mut bytes = flows * std::mem::size_of::<Flow>()
            + n_links * std::mem::size_of::<FluidLink>()
            + flows * 24; // finish table + step-cursor bookkeeping
        if self.cc.is_some() {
            // cap/fed columns per flow, vq/tx integrals plus the epoch
            // pass's two scratch columns per link, CC state per live
            // endpoint
            bytes += flows * 2 * 8 + n_links * 4 * 8 + 2 * n * CC_ENDPOINT_BYTES;
        }
        let workers = self.cores.unwrap_or(1).clamp(1, self.iters.max(1));
        bytes * workers
    }
}

/// Aggregated outcome of one cell (`iters` iterations).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScaleResult {
    /// Per-iteration collective completion time (last rank's finish).
    pub cct_ns: Vec<SimTime>,
    /// Median / p99 over every per-rank finish across all iterations —
    /// the tail the paper's figures plot.
    pub p50_ns: SimTime,
    pub p99_ns: SimTime,
    /// Every rank finished every step in every iteration.
    pub completed: bool,
    // engine accounting, summed over iterations
    pub flows: u64,
    pub fluid_started: u64,
    pub packet_started: u64,
    pub pkts_walked: u64,
    pub resolves: u64,
    /// CC plane epochs processed (0 when `cc` is off) — part of the
    /// byte-compared result, so determinism suites pin the coupled
    /// plane too.
    pub cc_epochs: u64,
    /// Flow-epochs that saw a synthesized ECN mark.
    pub cc_marks: u64,
}

impl ScaleResult {
    pub fn max_cct_ns(&self) -> SimTime {
        self.cct_ns.iter().copied().max().unwrap_or(0)
    }
}

/// Per-rank step-cursor state (see module docs for the execution model).
#[derive(Clone, Debug)]
struct RankState {
    cursor: usize,
    ready_at: SimTime,
    issued: bool,
    send_done: Option<SimTime>,
    recv_done: Option<SimTime>,
}

/// Everything one iteration contributes to the merged [`ScaleResult`].
struct IterOut {
    samples: Vec<SimTime>,
    cct: SimTime,
    completed: bool,
    flows: u64,
    fluid: u64,
    packet: u64,
    walked: u64,
    resolves: u64,
    cc_epochs: u64,
    cc_marks: u64,
}

/// One full iteration: fresh `FlowSim`, salt derived from `iter`, drain
/// to quiescence. Pure function of `(cell, scheds, iter)` — the
/// iteration-parallel runner relies on that.
fn run_iter(cell: &ScaleCell, scheds: &[Vec<Step>], iter: usize) -> IterOut {
    let n = scheds.len();
    let mut fs = FlowSim::new(&cell.fabric, FidelityPolicy::of(cell.fidelity), cell.sched);
    fs.ecmp_salt = cell.seed ^ (iter as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    if let Some(kind) = cell.cc {
        fs.enable_cc(kind, &cell.fabric);
    }
    for &(t, nf) in &cell.faults {
        fs.fault(t, nf);
    }
    let mut st = vec![
        RankState {
            cursor: 0,
            ready_at: 0,
            issued: false,
            send_done: None,
            recv_done: None,
        };
        n
    ];
    let mut arrivals: HashMap<(usize, usize), VecDeque<SimTime>> = HashMap::new();
    let mut flow_sender: HashMap<FlowId, usize> = HashMap::new();
    let mut finish: Vec<Option<SimTime>> = vec![None; n];

    for r in 0..n {
        try_advance(
            r, scheds, &mut st, &mut fs, &mut arrivals, &mut flow_sender, &mut finish,
            cell.spray,
        );
    }
    while let Some((f, t)) = fs.run_next_completion() {
        let s = *flow_sender.get(&f).expect("completion for unknown flow");
        let d = fs.flows[f as usize].dst as usize;
        debug_assert!(st[s].issued && st[s].send_done.is_none());
        st[s].send_done = Some(t);
        arrivals.entry((s, d)).or_default().push_back(t);
        try_advance(
            s, scheds, &mut st, &mut fs, &mut arrivals, &mut flow_sender, &mut finish,
            cell.spray,
        );
        try_advance(
            d, scheds, &mut st, &mut fs, &mut arrivals, &mut flow_sender, &mut finish,
            cell.spray,
        );
    }

    let mut out = IterOut {
        samples: Vec::with_capacity(n),
        cct: 0,
        completed: true,
        flows: fs.flows.len() as u64,
        fluid: fs.fluid_started,
        packet: fs.packet_started,
        walked: fs.pkts_walked,
        resolves: fs.resolves,
        cc_epochs: fs.cc_epochs,
        cc_marks: fs.cc_marks,
    };
    for r in 0..n {
        match finish[r] {
            Some(t) => {
                out.samples.push(t);
                out.cct = out.cct.max(t);
            }
            None => out.completed = false, // stalled on a partitioned fabric
        }
    }
    out
}

pub fn run_scale_cell(cell: &ScaleCell) -> ScaleResult {
    let n = cell.fabric.nodes;
    let topo = cell.fabric.topology();
    let cores = cell.cores.unwrap_or(1).max(1);

    // Per-rank schedule construction is O(n · steps) pure data — at
    // 4096+ ranks it is worth fanning out across the same core budget.
    let build = |r: usize| {
        if cell.hier {
            hier_allreduce(r, n, cell.elems, topo.hosts_per_leaf)
        } else {
            cell.kind.schedule(r, n, cell.elems)
        }
    };
    let scheds: Vec<Vec<Step>> = if cores > 1 && n >= 64 {
        let mut out: Vec<Vec<Step>> = vec![Vec::new(); n];
        let chunk = n.div_ceil(cores);
        std::thread::scope(|s| {
            for (ci, slot) in out.chunks_mut(chunk).enumerate() {
                let build = &build;
                s.spawn(move || {
                    for (j, dst) in slot.iter_mut().enumerate() {
                        *dst = build(ci * chunk + j);
                    }
                });
            }
        });
        out
    } else {
        (0..n).map(build).collect()
    };

    // Iterations are independent simulations; scatter them across
    // workers and merge in fixed iteration order — byte-identical to
    // the serial loop for any core count.
    let outs: Vec<IterOut> = if cores > 1 && cell.iters > 1 {
        let mut slots: Vec<Option<IterOut>> = (0..cell.iters).map(|_| None).collect();
        let chunk = cell.iters.div_ceil(cores);
        let scheds = &scheds;
        std::thread::scope(|s| {
            for (ci, slot) in slots.chunks_mut(chunk).enumerate() {
                s.spawn(move || {
                    for (j, dst) in slot.iter_mut().enumerate() {
                        *dst = Some(run_iter(cell, scheds, ci * chunk + j));
                    }
                });
            }
        });
        slots.into_iter().map(|o| o.expect("iteration ran")).collect()
    } else {
        (0..cell.iters).map(|i| run_iter(cell, &scheds, i)).collect()
    };

    let mut samples: Vec<SimTime> = Vec::with_capacity(n * cell.iters);
    let mut cct_ns = Vec::with_capacity(cell.iters);
    let mut completed = true;
    let (mut flows, mut fluid, mut packet, mut walked, mut resolves) = (0, 0, 0, 0, 0);
    let (mut cc_epochs, mut cc_marks) = (0, 0);
    for o in outs {
        samples.extend(o.samples);
        cct_ns.push(o.cct);
        completed &= o.completed;
        flows += o.flows;
        fluid += o.fluid;
        packet += o.packet;
        walked += o.walked;
        resolves += o.resolves;
        cc_epochs += o.cc_epochs;
        cc_marks += o.cc_marks;
    }

    samples.sort_unstable();
    ScaleResult {
        cct_ns,
        p50_ns: pct(&samples, 0.50),
        p99_ns: pct(&samples, 0.99),
        completed,
        flows,
        fluid_started: fluid,
        packet_started: packet,
        pkts_walked: walked,
        resolves,
        cc_epochs,
        cc_marks,
    }
}

/// Run `r` forward: issue its current step's send (once), match a queued
/// arrival against its recv half, and advance the cursor while both
/// halves are satisfied. The finish time of a step is the later of its
/// two halves — the blocking-step model shared with the packet engine.
#[allow(clippy::too_many_arguments)]
fn try_advance(
    r: usize,
    scheds: &[Vec<Step>],
    st: &mut [RankState],
    fs: &mut FlowSim,
    arrivals: &mut HashMap<(usize, usize), VecDeque<SimTime>>,
    flow_sender: &mut HashMap<FlowId, usize>,
    finish: &mut [Option<SimTime>],
    spray: bool,
) {
    loop {
        let Some(step) = scheds[r].get(st[r].cursor) else {
            if finish[r].is_none() {
                finish[r] = Some(st[r].ready_at);
            }
            return;
        };
        if !st[r].issued {
            st[r].issued = true;
            st[r].send_done = None;
            st[r].recv_done = None;
            match step.send {
                Some((to, c)) => {
                    let f = fs.inject_opt(st[r].ready_at, r, to, (c.len * 4) as u64, spray);
                    flow_sender.insert(f, r);
                }
                None => st[r].send_done = Some(st[r].ready_at),
            }
            if step.recv.is_none() {
                st[r].recv_done = Some(st[r].ready_at);
            }
        }
        if st[r].recv_done.is_none() {
            if let Some((from, _, _)) = step.recv {
                if let Some(t) = arrivals.get_mut(&(from, r)).and_then(|q| q.pop_front()) {
                    st[r].recv_done = Some(t.max(st[r].ready_at));
                }
            }
        }
        match (st[r].send_done, st[r].recv_done) {
            (Some(a), Some(b)) => {
                st[r].ready_at = a.max(b);
                st[r].cursor += 1;
                st[r].issued = false;
            }
            _ => return,
        }
    }
}

/// Nearest-rank percentile over a sorted sample vector.
fn pct(sorted: &[SimTime], q: f64) -> SimTime {
    if sorted.is_empty() {
        return 0;
    }
    let idx = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 10 G, 100 ns prop, 50 ns switch — cap 1.25 B/ns everywhere.
    fn base_cfg(nodes: usize) -> FabricCfg {
        let mut cfg = FabricCfg::cloudlab(nodes).with_link_gbps(10.0);
        cfg.prop_delay_ns = 100;
        cfg.switch_delay_ns = 50;
        cfg
    }

    #[test]
    fn ring_allreduce_cct_matches_hand_arithmetic() {
        // 4 ranks, single switch, fluid: every step moves one 4096 B chunk
        // per rank on disjoint links at full rate. Step time =
        // ceil(4096 / 1.25) + 2·prop + switch = 3277 + 250; 2(n−1) = 6
        // steps, perfectly synchronous.
        let mut cell = ScaleCell::new(base_cfg(4), CollectiveKind::AllReduceRing, 4096);
        cell.fidelity = FidelityMode::Flow;
        cell.iters = 1;
        let res = run_scale_cell(&cell);
        assert!(res.completed);
        assert_eq!(res.cct_ns, vec![6 * (3277 + 250)]);
        assert_eq!(res.p50_ns, 6 * (3277 + 250)); // all ranks identical
        assert_eq!(res.flows, 4 * 6);
        assert_eq!(res.packet_started, 0);
    }

    #[test]
    fn fidelity_engines_agree_on_bulk_ring_within_tolerance() {
        // chunk = 40 MTUs: store-and-forward re-serialization amortizes to
        // a few percent — the validation-grid bound is 15% (docs/SCALE.md)
        let elems = 4 * 40 * 1024; // chunk = 40960 elems = 40 MTUs
        let mut cell = ScaleCell::new(base_cfg(4), CollectiveKind::AllReduceRing, elems);
        cell.iters = 1;
        cell.fidelity = FidelityMode::Flow;
        let fluid = run_scale_cell(&cell);
        cell.fidelity = FidelityMode::Packet;
        let pkt = run_scale_cell(&cell);
        assert!(fluid.completed && pkt.completed);
        let (tf, tp) = (fluid.max_cct_ns(), pkt.max_cct_ns());
        assert!(tp >= tf, "packet {tp} must not beat fluid {tf}");
        assert!(
            (tp - tf) as f64 <= 0.15 * tf as f64,
            "packet {tp} vs fluid {tf} exceeds 15% tolerance"
        );
        assert!(pkt.pkts_walked >= 4 * 6 * 40);
    }

    #[test]
    fn hierarchical_allreduce_runs_on_a_fat_tree() {
        let cfg = base_cfg(16).with_fat_tree(2, 2, 2, 2);
        let mut cell = ScaleCell::new(cfg, CollectiveKind::AllReduceRing, 16 * 64);
        cell.hier = true; // rack = hosts_per_leaf = 4
        cell.iters = 2;
        let res = run_scale_cell(&cell);
        assert!(res.completed);
        assert!(res.p99_ns >= res.p50_ns);
        assert!(res.max_cct_ns() > 0);
        // leaders run 10-step schedules, members 4 → far fewer flows than
        // the flat ring's 16 ranks × 30 steps
        assert!(res.flows < 2 * 16 * 30);
    }

    #[test]
    fn scale_cells_replay_identically_on_both_backends() {
        let mk = |sched: SchedKind| {
            let cfg = base_cfg(16).with_fat_tree(2, 2, 2, 2);
            let mut cell = ScaleCell::new(cfg, CollectiveKind::AllReduceRing, 16 * 256);
            cell.sched = sched;
            cell.iters = 2;
            cell.faults = vec![(5_000, NetFault::LinkDown(16))];
            run_scale_cell(&cell)
        };
        let a = mk(SchedKind::Wheel);
        let b = mk(SchedKind::Wheel);
        assert_eq!(a, b, "replay must be identical");
        let c = mk(SchedKind::Heap);
        assert_eq!(a, c, "wheel and heap must agree");
    }

    #[test]
    fn scale_cell_cores_are_wall_clock_only() {
        // partitioning by iteration (plus parallel schedule build) must
        // not perturb a single bit of the merged result
        let mk = |cores: Option<usize>| {
            let cfg = base_cfg(64).with_fat_tree(2, 4, 4, 8);
            let mut cell = ScaleCell::new(cfg, CollectiveKind::AllReduceRing, 64 * 64);
            cell.hier = true;
            cell.iters = 3;
            cell.faults = vec![(5_000, NetFault::LinkDown(64))];
            cell.cores = cores;
            run_scale_cell(&cell)
        };
        let serial = mk(None);
        assert!(serial.completed);
        assert_eq!(serial, mk(Some(2)));
        assert_eq!(serial, mk(Some(4)));
        assert_eq!(serial, mk(Some(64))); // more workers than iterations
    }

    #[test]
    fn ecmp_iterations_reroll_while_spray_stays_balanced() {
        // on a fat-tree with contending cross-pod flows, hash-pinned ECMP
        // tails vary across iterations (different collision patterns);
        // the p99/p50 spread quantifies it
        let cfg = base_cfg(16).with_fat_tree(2, 2, 2, 2);
        let mut cell = ScaleCell::new(cfg, CollectiveKind::AllToAll, 16 * 64);
        cell.iters = 3;
        cell.fidelity = FidelityMode::Flow;
        let pinned = run_scale_cell(&cell);
        assert!(pinned.completed);
        cell.spray = true;
        let sprayed = run_scale_cell(&cell);
        assert!(sprayed.completed);
        // both produce valid tails; sprayed never does worse at the median
        // by more than the pinned spread (sanity, not a theorem)
        assert!(sprayed.p50_ns <= pinned.p99_ns);
    }

    #[test]
    fn every_cc_kind_drives_fluid_cells_through_the_shared_seam() {
        // the tentpole contract: EVERY policy — rate-based, window-based,
        // credit-based — runs a fluid cell to completion via rate caps
        // and synthesized signals, with zero per-algorithm code in the
        // engine (the zero-branch guard in tests/determinism.rs pins
        // the latter)
        for kind in CcKind::ALL {
            let mut cell = ScaleCell::new(base_cfg(4), CollectiveKind::AllReduceRing, 4 * 1024);
            cell.fidelity = FidelityMode::Flow;
            cell.iters = 1;
            cell.cc = Some(kind);
            let res = run_scale_cell(&cell);
            assert!(res.completed, "{} must complete a fluid ring", kind.name());
            assert!(res.cc_epochs > 0, "{} must tick epochs", kind.name());
        }
    }

    #[test]
    fn cc_coupled_cells_replay_identically() {
        let mk = || {
            let cfg = base_cfg(16).with_fat_tree(2, 2, 2, 2);
            let mut cell = ScaleCell::new(cfg, CollectiveKind::AllReduceRing, 16 * 256);
            cell.iters = 2;
            cell.cc = Some(CcKind::Dcqcn);
            cell.faults = vec![(5_000, NetFault::LinkDown(16))];
            run_scale_cell(&cell)
        };
        let a = mk();
        assert!(a.cc_epochs > 0);
        assert_eq!(a, mk(), "CC-coupled replay must be identical");
    }

    #[test]
    fn est_cluster_bytes_charges_fluid_and_cc_state() {
        let cfg = base_cfg(64).with_fat_tree(2, 4, 4, 8);
        let cell = ScaleCell::new(cfg, CollectiveKind::AllReduceRing, 64 * 64);
        let plain = cell.est_cluster_bytes();
        // the fluid tables alone must register: 64 ranks × 126 steps of
        // 64 B flows is past 500 KiB before any CC state
        assert!(plain > 64 * 2 * 63 * std::mem::size_of::<Flow>());
        let coupled = cell.clone().with_cc(CcKind::Swift).est_cluster_bytes();
        assert!(coupled > plain, "CC plane state must be charged");
        // endpoint state alone adds ≥ 2·n·CC_ENDPOINT_BYTES
        assert!(coupled - plain >= 2 * 64 * CC_ENDPOINT_BYTES);
        // concurrent iterations multiply the resident estimate, capped
        // by how many iterations exist
        let wide = cell.clone().with_cores(2).est_cluster_bytes();
        assert_eq!(wide, 2 * plain);
        let over = cell.clone().with_cores(64).est_cluster_bytes();
        assert_eq!(over, cell.iters * plain); // iters = 2 default
    }
}
