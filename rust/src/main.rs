//! `optinic` — the launcher. Thin CLI over the coordinator: training runs,
//! serving runs, collective sweeps, hardware reports, and fault-injection
//! campaigns, all configurable from a TOML-subset file + `--set` overrides.
//!
//! Examples:
//!   optinic train --model tiny --env hyperstack-4 --transport optinic --steps 20
//!   optinic serve --model tiny --transport roce --requests 64
//!   optinic serve --qps 400 --tenants 2 --arrival diurnal --topo leaf-spine
//!   optinic sweep --collective allreduce --mb 20,40,60,80
//!   optinic hw
//!   optinic faults --transport roce --duration-ms 50
//!   optinic scenario --name perfect-storm --transport optinic --topo leaf-spine
//!   optinic train --config configs/fig3.toml --set train.steps=100

use anyhow::{anyhow, Result};

use optinic::cc::CcKind;
use optinic::collectives::{CollectiveKind, CollectiveSpec, Driver, Workspace};
use optinic::coordinator::{EnvKind, ServeCfg, Server, TrainCfg, Trainer};
use optinic::hw;
use optinic::scenarios::{run_scenario_cell, ScenarioCell, ScenarioKind};
use optinic::runtime::Engine;
use optinic::sim::cluster::{Cluster, ClusterCfg};
use optinic::transport::TransportKind;
use optinic::util::bench::{jf, js, run_collective_cell, CollectiveCell, InputSet, Table};
use optinic::util::cli::{Args, Help};
use optinic::util::sweep::SweepGrid;
use optinic::util::config::Config;
use optinic::util::json::Json;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env(true, &["json", "help", "verbose"]).map_err(|e| anyhow!(e))?;
    if args.has_flag("help") || args.subcommand.is_none() {
        print!("{}", help().render());
        return Ok(());
    }
    let mut cfg = match args.opt("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::empty(),
    };
    for (k, v) in &args.options {
        if k == "set" {
            let (key, val) = v
                .split_once('=')
                .ok_or_else(|| anyhow!("--set expects key=value"))?;
            cfg.set_raw(key, val).map_err(|e| anyhow!(e))?;
        }
    }

    match args.subcommand.as_deref().unwrap() {
        "train" => cmd_train(&args, &cfg),
        "serve" => cmd_serve(&args, &cfg),
        "sweep" => cmd_sweep(&args, &cfg),
        "hw" => cmd_hw(&args),
        "faults" => cmd_faults(&args),
        "scenario" => cmd_scenario(&args),
        other => Err(anyhow!("unknown subcommand '{other}' (see --help)")),
    }
}

fn help() -> Help {
    Help::new("optinic", "resilient, tail-optimal RDMA transport for distributed ML (paper reproduction)")
        .item("train", "distributed training run (Fig 2/3): --model --env --transport --steps --pattern")
        .item("serve", "inference serving run (Fig 4): --model --env --transport --requests")
        .item("serve (open-loop)", "multi-tenant SLO run: --qps --tenants --arrival poisson|diurnal --slo-ttft-ms --topo single|leaf-spine")
        .item("sweep", "collective microbenchmark (Fig 5/6): --collective --mb --transport --cc --iters --topo single|leaf-spine|fat-tree [--leaves --spines --pods --core --oversub]")
        .item("sweep (scale)", "hybrid-fidelity scale sweep (docs/SCALE.md): --fidelity packet|flow|hybrid [--hier] [--cc <kind>] --topo fat-tree --nodes 1024")
        .item("hw", "hardware model report (Tables 4/5)")
        .item("faults", "SEU fault-injection campaign: --transport --duration-ms --accel")
        .item("scenario", "adversarial burst/fault scenario (docs/SCENARIOS.md): --name --transport --cc --topo --iters (no --name lists the catalog)")
        .item("--config FILE", "TOML config; --set key=value overrides")
        .item(
            "--jobs N",
            "sweep workers (env OPTINIC_JOBS; default: all cores, memory-capped for large --mb — see docs/PERF.md)",
        )
        .item(
            "--cores N",
            "worker threads INSIDE each simulation (partitioned engine, env OPTINIC_CORES); byte-identical results for any N — docs/PERF.md §Partitioned engine",
        )
        .item("--json", "machine-readable output")
}

fn parse_transport(s: &str) -> Result<TransportKind> {
    TransportKind::parse(s).ok_or_else(|| anyhow!("unknown transport '{s}'"))
}

fn parse_env(s: &str) -> Result<EnvKind> {
    EnvKind::parse(s).ok_or_else(|| anyhow!("unknown environment '{s}'"))
}

fn cmd_train(args: &Args, cfg: &Config) -> Result<()> {
    let model = args.opt_or("model", &cfg.str("train.model", "tiny"));
    let env = parse_env(&args.opt_or("env", &cfg.str("train.env", "hyperstack-4")))?;
    let transport =
        parse_transport(&args.opt_or("transport", &cfg.str("train.transport", "optinic")))?;
    let mut tc = TrainCfg::new(&model, env, transport);
    tc.steps = args.opt_usize("steps", cfg.usize("train.steps", 30));
    tc.lr = args.opt_f64("lr", cfg.f64("train.lr", 0.05)) as f32;
    tc.seed = args.opt_u64("seed", cfg.i64("train.seed", 42) as u64);
    tc.bg_load = args.opt_f64("bg-load", cfg.f64("train.bg_load", 0.2));
    tc.eval_every = args.opt_usize("eval-every", cfg.usize("train.eval_every", 10));
    if args.opt_or("pattern", &cfg.str("train.pattern", "zero3")) == "dp" {
        tc.pattern = optinic::coordinator::CommPattern::DataParallel;
    }
    let mut engine = Engine::load_default()?;
    println!(
        "training {model} on {} over {} ({} steps)...",
        env.name(),
        transport.name(),
        tc.steps
    );
    let result = Trainer::new(tc, &mut engine)?.run()?;
    let mut t = Table::new(
        "Training run",
        &["step", "loss", "sim time", "comm", "data loss %", "eval acc"],
    );
    for r in &result.records {
        t.row(&[
            r.step.to_string(),
            format!("{:.4}", r.train_loss),
            optinic::sim::fmt_time(r.sim_time_ns),
            optinic::sim::fmt_time(r.comm_ns),
            format!("{:.3}", r.loss_fraction * 100.0),
            r.eval_accuracy
                .map(|a| format!("{:.3}", a))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();
    println!(
        "final accuracy {:.3}; total simulated time {}; avg data loss {:.3}%",
        result.final_accuracy,
        optinic::sim::fmt_time(result.total_sim_ns),
        result.total_loss_fraction * 100.0
    );
    if args.has_flag("json") {
        let mut o = Json::obj();
        o.set("final_accuracy", result.final_accuracy as f64)
            .set("total_sim_ns", result.total_sim_ns)
            .set("loss_fraction", result.total_loss_fraction);
        println!("{}", o.to_string_pretty());
    }
    Ok(())
}

fn cmd_serve(args: &Args, cfg: &Config) -> Result<()> {
    // Any open-loop knob routes to the multi-tenant serving subsystem
    // (no inference engine needed — it is a pure DES experiment). The
    // legacy flags (--model/--requests/--rps) keep the closed-loop Fig 4
    // accuracy path below.
    let open_loop = ["qps", "tenants", "arrival", "slo-ttft-ms", "topo"]
        .into_iter()
        .any(|k| args.opt(k).is_some())
        || cfg.str_opt("serve.arrival").is_some();
    if open_loop {
        return cmd_serve_open_loop(args, cfg);
    }
    let model = args.opt_or("model", &cfg.str("serve.model", "tiny"));
    let env = parse_env(&args.opt_or("env", &cfg.str("serve.env", "hyperstack-4")))?;
    let transport =
        parse_transport(&args.opt_or("transport", &cfg.str("serve.transport", "optinic")))?;
    let mut sc = ServeCfg::new(&model, env, transport);
    sc.num_requests = args.opt_usize("requests", cfg.usize("serve.requests", 48));
    sc.arrival_rps = args.opt_f64("rps", cfg.f64("serve.rps", 300.0));
    sc.bg_load = args.opt_f64("bg-load", cfg.f64("serve.bg_load", 0.2));
    sc.seed = args.opt_u64("seed", 7);
    let mut engine = Engine::load_default()?;
    println!(
        "serving {model} on {} over {} ({} requests)...",
        env.name(),
        transport.name(),
        sc.num_requests
    );
    let mut res = Server::new(sc, &mut engine)?.run()?;
    println!(
        "throughput {:.1} tok/s | TTFT mean {} p99 {} | accuracy lossy {:.3} clean {:.3} | data loss {:.3}%",
        res.throughput_tps(),
        optinic::util::bench::fmt_ns(res.ttft_ns.mean()),
        optinic::util::bench::fmt_ns(res.ttft_ns.p99()),
        res.lossy_accuracy,
        res.clean_accuracy,
        res.data_loss_fraction * 100.0
    );
    Ok(())
}

/// `optinic serve --qps 400 --tenants 2 --arrival diurnal --topo leaf-spine`:
/// the open-loop disaggregated-pool path (PR 6). Reports per-tenant
/// TTFT/TPOT tails, queueing delay, SLO attainment, and KV-migration
/// traffic between the prefill and decode pools.
fn cmd_serve_open_loop(args: &Args, cfg: &Config) -> Result<()> {
    use optinic::serving::{run_serving_cell, ArrivalKind, ServingCell};

    let transport =
        parse_transport(&args.opt_or("transport", &cfg.str("serve.transport", "optinic")))?;
    let arrival_s = args.opt_or("arrival", &cfg.str("serve.arrival", "poisson"));
    let arrival = ArrivalKind::parse(&arrival_s)
        .ok_or_else(|| anyhow!("unknown arrival process '{arrival_s}' (poisson | diurnal)"))?;
    let topo = args.opt_or("topo", &cfg.str("serve.topo", "single"));
    let leaf_spine = match topo.as_str() {
        "single" | "single-switch" => false,
        "leaf-spine" | "leafspine" | "clos" => true,
        other => return Err(anyhow!("unknown topology '{other}' (single | leaf-spine)")),
    };
    let mut cell = ServingCell::new(transport, arrival, leaf_spine);
    cell.qps = args.opt_f64("qps", cfg.f64("serve.qps", 400.0));
    cell.tenants = args.opt_usize("tenants", cfg.usize("serve.tenants", 2)).max(1);
    cell.requests_per_tenant = args.opt_usize("requests", cfg.usize("serve.requests", 24));
    cell.bg_load = args.opt_f64("bg-load", cfg.f64("serve.bg_load", 0.2));
    cell.slo.ttft_ms = args.opt_f64("slo-ttft-ms", cfg.f64("serve.slo_ttft_ms", 20.0));
    cell.slo.tpot_ms = args.opt_f64("slo-tpot-ms", cfg.f64("serve.slo_tpot_ms", 4.0));
    cell.seed = args.opt_u64("seed", 7);

    println!(
        "open-loop serving: {} tenants at {:.0} qps ({} arrivals) over {} on {} fabric...",
        cell.tenants,
        cell.qps,
        arrival.name(),
        transport.name(),
        cell.topo_name()
    );
    let out = run_serving_cell(&cell);
    let slo = out.get("slo").expect("serving row has slo block");
    let mut table = Table::new(
        "Per-tenant SLO report",
        &[
            "tenant", "done", "TTFT p50", "TTFT p99", "TTFT p99.9", "TPOT p50", "TPOT p99",
            "queue p99", "SLO",
        ],
    );
    if let Some(Json::Arr(rows)) = slo.get("tenants") {
        for row in rows {
            let g = |k: &str| row.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            table.row(&[
                row.get("tenant")
                    .and_then(Json::as_str)
                    .unwrap_or("?")
                    .to_string(),
                row.get("completed")
                    .and_then(Json::as_i64)
                    .unwrap_or(0)
                    .to_string(),
                optinic::util::bench::fmt_ns(g("ttft_p50_ns")),
                optinic::util::bench::fmt_ns(g("ttft_p99_ns")),
                optinic::util::bench::fmt_ns(g("ttft_p999_ns")),
                optinic::util::bench::fmt_ns(g("tpot_p50_ns")),
                optinic::util::bench::fmt_ns(g("tpot_p99_ns")),
                optinic::util::bench::fmt_ns(g("queue_delay_p99_ns")),
                format!("{:.1}%", g("slo_attainment") * 100.0),
            ]);
        }
    }
    table.print();
    let gi = |k: &str| slo.get(k).and_then(Json::as_i64).unwrap_or(0);
    println!(
        "completed {}/{} requests | {:.1} tok/s | KV moved {:.2} MB over {} transfers ({} B lost)",
        gi("requests_completed"),
        gi("requests_offered"),
        slo.get("throughput_tps").and_then(Json::as_f64).unwrap_or(0.0),
        gi("kv_bytes_moved") as f64 / 1e6,
        gi("kv_transfers"),
        gi("kv_bytes_lost"),
    );
    if args.has_flag("json") {
        println!("{}", out.to_string_pretty());
    }
    Ok(())
}

fn cmd_sweep(args: &Args, cfg: &Config) -> Result<()> {
    let kind = CollectiveKind::parse(
        &args.opt_or("collective", &cfg.str("sweep.collective", "allreduce")),
    )
    .ok_or_else(|| anyhow!("unknown collective"))?;
    let transports: Vec<TransportKind> = args
        .opt_or("transport", &cfg.str("sweep.transport", "roce,optinic,optinic-hw"))
        .split(',')
        .map(parse_transport)
        .collect::<Result<_>>()?;
    let mbs: Vec<usize> = args
        .opt_or("mb", "20,40,60,80")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let iters = args.opt_usize("iters", 5);
    let nodes = args.opt_usize("nodes", 8);
    let bg = args.opt_f64("bg-load", 0.2);
    // 0 = serial legacy loop; N ≥ 1 = partitioned engine inside each
    // simulation (wall-clock only: merged output is byte-identical)
    let cores = args.opt_usize(
        "cores",
        optinic::util::sweep::explicit_cores().unwrap_or(cfg.usize("sweep.cores", 0)),
    );
    // --topo leaf-spine reshapes the fabric into a two-tier Clos
    // (--leaves/--spines size it; defaults 2×2 — see docs/TOPOLOGY.md);
    // --topo fat-tree builds the 3-tier multi-pod Clos: --pods/--leaves/
    // --spines size each pod, --core the shared top tier, and --oversub R
    // derives spines-per-pod from the host count when --spines is absent
    // (docs/SCALE.md §Fat-tree)
    #[derive(Clone, Copy)]
    enum Topo {
        Single,
        LeafSpine,
        FatTree { pods: usize, core: usize },
    }
    let topo_name = args.opt_or("topo", &cfg.str("sweep.topo", "single"));
    let leaves = args.opt_usize("leaves", cfg.usize("sweep.leaves", 2));
    let mut spines = args.opt_usize("spines", cfg.usize("sweep.spines", 2));
    let topo = match topo_name.as_str() {
        "single" => Topo::Single,
        "leaf-spine" | "leafspine" | "clos" => Topo::LeafSpine,
        "fat-tree" | "fattree" => {
            let pods = args.opt_usize("pods", cfg.usize("sweep.pods", 2));
            if pods * leaves == 0 || nodes % (pods * leaves) != 0 {
                return Err(anyhow!(
                    "--topo fat-tree needs --nodes ({nodes}) divisible by pods*leaves ({})",
                    pods * leaves
                ));
            }
            if let Some(r) = args.opt("oversub") {
                if args.opt("spines").is_none() {
                    let r: f64 = r
                        .parse()
                        .map_err(|_| anyhow!("--oversub expects a ratio, got '{r}'"))?;
                    let hosts_per_leaf = nodes / (pods * leaves);
                    spines = ((hosts_per_leaf as f64 / r).round() as usize).max(1);
                }
            }
            let core =
                args.opt_usize("core", cfg.usize("sweep.core", ((pods * spines) / 2).max(1)));
            Topo::FatTree { pods, core }
        }
        other => {
            return Err(anyhow!(
                "unknown topology '{other}' (single | leaf-spine | fat-tree)"
            ))
        }
    };
    let build_fab = |nodes: usize| {
        let fab = optinic::net::FabricCfg::cloudlab(nodes);
        match topo {
            Topo::Single => fab,
            Topo::LeafSpine => fab.with_leaf_spine(leaves, spines),
            Topo::FatTree { pods, core } => fab.with_fat_tree(pods, leaves, spines, core),
        }
    };

    // --cc forces one algorithm across every transport (CC ablations);
    // absent, each transport keeps its paper-default scheme. Parsed
    // BEFORE the fidelity fork so fluid/hybrid cells honor it too: the
    // scale runner routes it through the same RateAuthority seam the
    // packet engine uses (it used to be silently dropped here).
    let cc = match args
        .opt("cc")
        .map(str::to_string)
        .or_else(|| cfg.str_opt("sweep.cc"))
    {
        Some(s) => Some(
            optinic::cc::CcKind::parse(&s).ok_or_else(|| anyhow!("unknown cc '{s}'"))?,
        ),
        None => None,
    };

    // --fidelity routes the sweep through the hybrid packet/flow engine
    // (docs/SCALE.md) instead of the full packet cluster — the only path
    // that holds 1k-rank fat-trees. packet = in-engine reference, flow =
    // all-fluid, hybrid = fluid bulk + packet where tails are decided.
    // --hier swaps in the rack-aware hierarchical AllReduce.
    if let Some(fid) = args.opt("fidelity") {
        let fid = optinic::net::FidelityMode::parse(fid)
            .ok_or_else(|| anyhow!("unknown fidelity '{fid}' (packet | flow | hybrid)"))?;
        let hier = args.has_flag("hier");
        let mut table = Table::new(
            &format!("{} tail CCT — {} fidelity", kind.name(), fid.name()),
            &["transport", "cc", "topo", "size (MB)", "p50 CCT", "p99 CCT", "flows fluid/pkt"],
        );
        let mut rows = Vec::new();
        for transport in &transports {
            for &mb in &mbs {
                let elems = mb * 1024 * 1024 / 4;
                let mut cell =
                    optinic::sim::ScaleCell::new(build_fab(nodes), kind, elems);
                cell.fidelity = fid;
                cell.iters = iters;
                cell.seed = 11;
                cell.hier = hier;
                if cores >= 1 {
                    cell.cores = Some(cores);
                }
                // OptiNIC sprays per packet; everyone else pins by hash
                cell.spray = matches!(
                    transport,
                    TransportKind::Optinic | TransportKind::OptinicHw
                );
                cell.cc = cc;
                let res = optinic::sim::run_scale_cell(&cell);
                table.row(&[
                    transport.name().to_string(),
                    cc.map_or("default", |k| k.canonical_name()).to_string(),
                    topo_name.clone(),
                    mb.to_string(),
                    optinic::util::bench::fmt_ns(res.p50_ns as f64),
                    optinic::util::bench::fmt_ns(res.p99_ns as f64),
                    format!("{}/{}", res.fluid_started, res.packet_started),
                ]);
                let mut o = Json::obj();
                o.set("transport", transport.name());
                o.set("topo", topo_name.as_str());
                o.set("fidelity", fid.name());
                o.set("hier", hier);
                o.set("mb", mb);
                o.set("ranks", nodes);
                o.set("p50_ns", res.p50_ns);
                o.set("p99_ns", res.p99_ns);
                o.set("completed", res.completed);
                o.set("fluid_flows", res.fluid_started);
                o.set("packet_flows", res.packet_started);
                if let Some(k) = cc {
                    o.set("cc", k.canonical_name());
                    o.set("cc_epochs", res.cc_epochs);
                }
                rows.push(o);
            }
        }
        table.print();
        if args.has_flag("json") {
            let mut o = Json::obj();
            o.set("cells", Json::Arr(rows));
            println!("{}", o.to_string_pretty());
        }
        return Ok(());
    }
    // 0 = "let the runner decide" (OPTINIC_JOBS, else all cores)
    let jobs = args.opt_usize("jobs", cfg.usize("sweep.jobs", 0));

    // declare the transport × size grid as data and hand it to the
    // deterministic multicore sweep runner (docs/PERF.md §Parallel sweeps)
    let mut cells = Vec::with_capacity(transports.len() * mbs.len());
    for transport in &transports {
        for &mb in &mbs {
            let elems = mb * 1024 * 1024 / 4;
            let fab = build_fab(nodes);
            let mut cell = CollectiveCell::new(fab, *transport, kind, elems);
            cell.seed = 11;
            cell.bg_load = bg;
            cell.iters = iters;
            cell.cc = cc;
            cell.exchange_stats = true;
            cell.reliable = !matches!(
                transport,
                TransportKind::Optinic | TransportKind::OptinicHw
            );
            if cores >= 1 {
                cell.cores = Some(cores);
            }
            cells.push(cell);
        }
    }
    let inputs = InputSet::ones(cells.iter().map(|c| c.elems).max().unwrap_or(0));
    let jobs = if jobs >= 1 {
        jobs
    } else {
        // no explicit --jobs: derive the default from the per-cell
        // buffer footprint so large --mb sweeps fit commodity machines,
        // then divide the core budget by --cores so multi-threaded cells
        // don't oversubscribe the machine (jobs × cores ≤ CPUs)
        let cell_bytes = cells.iter().map(|c| c.est_cluster_bytes()).max().unwrap_or(0);
        optinic::util::sweep::jobs_bounded_by_cell_bytes(cell_bytes)
            .min(optinic::util::sweep::jobs_with_cores(cores.max(1)))
    };
    let grid = SweepGrid::new("optinic sweep", cells).with_jobs(jobs);
    let report = grid.run(|_, cell| run_collective_cell(cell, &inputs));

    let mut table = Table::new(
        &format!("{} completion time", kind.name()),
        &["transport", "cc", "topo", "size (MB)", "mean CCT", "p99 CCT", "loss %"],
    );
    for (cell, r) in grid.cells.iter().zip(&report.results) {
        table.row(&[
            cell.transport.name().to_string(),
            js(r, "cc"),
            js(r, "topo"),
            cell.size_mb().to_string(),
            optinic::util::bench::fmt_ns(jf(r, "mean_ns")),
            optinic::util::bench::fmt_ns(jf(r, "p99_ns")),
            format!("{:.3}", jf(r, "loss_pct")),
        ]);
    }
    table.print();
    println!(
        "sweep: {} cells on {} jobs in {}",
        report.results.len(),
        report.jobs,
        optinic::util::bench::fmt_ns(report.wall_ns)
    );
    if args.has_flag("json") {
        let mut o = Json::obj();
        o.set("cells", Json::Arr(report.results.clone()));
        o.set("wall", report.wall_json());
        println!("{}", o.to_string_pretty());
    }
    Ok(())
}

fn cmd_hw(args: &Args) -> Result<()> {
    let mut t4 = Table::new(
        "Table 4: QP scalability",
        &["metric", "RoCE", "IRN", "SRNIC", "Falcon", "UCCL", "OptiNIC"],
    );
    let kinds = TransportKind::ALL;
    let row = |name: &str, f: &dyn Fn(TransportKind) -> String| -> Vec<String> {
        std::iter::once(name.to_string())
            .chain(kinds.iter().map(|k| f(*k)))
            .collect()
    };
    t4.row(&row("NIC state per QP (B)", &|k| {
        hw::qp_state::breakdown(k).total().to_string()
    }));
    t4.row(&row("max QPs (4 MiB SRAM)", &|k| {
        format!("{:.1}K", hw::qp_state::max_qps(k) as f64 / 1000.0)
    }));
    t4.row(&row("cluster size", &|k| {
        let c = hw::qp_state::cluster_size(k);
        if c >= 1000 {
            format!("{:.1}K", c as f64 / 1000.0)
        } else {
            c.to_string()
        }
    }));
    t4.print();

    let mut t5 = Table::new(
        "Table 5: hardware resources @ 10K QPs (Alveo U250 model)",
        &["metric", "RoCE", "IRN", "SRNIC", "Falcon", "UCCL", "OptiNIC"],
    );
    let reports: Vec<_> = kinds.iter().map(|k| hw::synthesize(*k)).collect();
    let rrow = |name: &str, f: &dyn Fn(&hw::ResourceReport) -> String| -> Vec<String> {
        std::iter::once(name.to_string())
            .chain(reports.iter().map(f))
            .collect()
    };
    t5.row(&rrow("LUT", &|r| format!("{:.1}K", r.lut / 1000.0)));
    t5.row(&rrow("LUTRAM", &|r| format!("{:.1}K", r.lutram / 1000.0)));
    t5.row(&rrow("FF", &|r| format!("{:.1}K", r.ff / 1000.0)));
    t5.row(&rrow("BRAM", &|r| format!("{:.0}", r.bram)));
    t5.row(&rrow("Power (W)", &|r| format!("{:.1}", r.power_w)));
    t5.row(&rrow("MTBF (hrs)", &|r| format!("{:.1}", r.mtbf_hours)));
    t5.print();

    if args.has_flag("json") {
        let mut o = Json::obj();
        for r in &reports {
            let mut e = Json::obj();
            e.set("lut", r.lut)
                .set("bram", r.bram)
                .set("power_w", r.power_w)
                .set("mtbf_hours", r.mtbf_hours);
            o.set(r.kind.name(), e);
        }
        println!("{}", o.to_string_pretty());
    }
    Ok(())
}

fn cmd_faults(args: &Args) -> Result<()> {
    let transport = parse_transport(&args.opt_or("transport", "roce"))?;
    let duration_ms = args.opt_u64("duration-ms", 50);
    let accel = args.opt_f64("accel", 2e7);
    let horizon = duration_ms * optinic::sim::MS;

    let mut fab = optinic::net::FabricCfg::cloudlab(4);
    fab.corrupt_prob = 0.0;
    let mut cluster = Cluster::new(ClusterCfg::new(fab, transport).with_seed(3));
    let n = hw::fault::schedule_faults(&mut cluster, transport, horizon, accel, 3);
    println!(
        "{}: scheduled {n} SEU events over {duration_ms} ms (accel {accel:.0e})",
        transport.name()
    );

    // run collectives continuously under fault injection
    let elems = 64 * 1024;
    let ws = Workspace::new(&mut cluster, elems, 1);
    let inputs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; elems]).collect();
    let mut driver = Driver::new(1);
    let mut completed = 0;
    let mut failed = 0;
    while cluster.time < horizon {
        ws.load_inputs(&mut cluster, &inputs);
        let mut spec = CollectiveSpec::new(CollectiveKind::AllReduceRing, elems);
        if !matches!(transport, TransportKind::Optinic | TransportKind::OptinicHw) {
            spec = spec.reliable();
        }
        // cap each iteration so a stalled QP doesn't hang the campaign
        cluster.cfg.max_sim_time = cluster.time + 100 * optinic::sim::MS;
        let res = driver.run(&mut cluster, &ws, &spec);
        if res.completed && !res.per_rank.iter().any(|r| r.failed) {
            completed += 1;
        } else {
            failed += 1;
            break; // a stalled reliable QP never recovers without re-setup
        }
    }
    let out = hw::fault::outcome(&cluster, failed == 0);
    println!(
        "collectives completed={completed} failed={failed} | faults scheduled={} injected={} | stalled QPs={}",
        out.faults_scheduled, out.faults_injected, out.stalled_qps
    );
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<()> {
    let Some(name) = args.opt("name") else {
        println!("scenario catalog (docs/SCENARIOS.md):");
        for k in ScenarioKind::ALL {
            println!("  {}", k.name());
        }
        return Ok(());
    };
    let scenario =
        ScenarioKind::parse(name).ok_or_else(|| anyhow!("unknown scenario '{name}'"))?;
    let transport = parse_transport(&args.opt_or("transport", "optinic"))?;
    let leaf_spine = match args.opt_or("topo", "leaf-spine").as_str() {
        "single" => false,
        "leaf-spine" | "leafspine" => true,
        other => return Err(anyhow!("unknown topo '{other}'")),
    };
    let mut cell = ScenarioCell::new(scenario, transport, leaf_spine);
    if let Some(cc) = args.opt("cc") {
        cell.cc = Some(CcKind::parse(cc).ok_or_else(|| anyhow!("unknown cc '{cc}'"))?);
    }
    cell.iters = args.opt_usize("iters", cell.iters);
    cell.elems = args.opt_usize("kb", cell.elems * 4 / 1024) * 1024 / 4;
    cell.seed = args.opt_u64("seed", cell.seed);

    let out = run_scenario_cell(&cell);
    if args.has_flag("json") {
        println!("{}", out.to_string_pretty());
        return Ok(());
    }
    println!(
        "scenario {} on {} ({}, cc {}): completions {}/{}{}",
        scenario.name(),
        transport.name(),
        cell.topo_name(),
        out.get("cc").and_then(Json::as_str).unwrap_or("default"),
        out.get("completions").and_then(Json::as_i64).unwrap_or(0),
        cell.iters,
        if out.get("completed_all").and_then(Json::as_bool) == Some(true) {
            ""
        } else {
            "  ** STALLED **"
        }
    );
    println!(
        "  p99 CCT {} | tta proxy {} | stalled QPs {} | bytes lost {}",
        optinic::sim::fmt_time(out.get("p99_ns").and_then(Json::as_i64).unwrap_or(0) as u64),
        optinic::sim::fmt_time(
            out.get("tta_proxy_ns").and_then(Json::as_i64).unwrap_or(0) as u64
        ),
        out.get("stalled_qps").and_then(Json::as_i64).unwrap_or(0),
        out.get("bytes_lost").and_then(Json::as_i64).unwrap_or(0),
    );
    println!(
        "  faults scheduled {} injected {} | net faults {} | spine plan {} | recovery {}",
        out.get("faults_scheduled").and_then(Json::as_i64).unwrap_or(0),
        out.get("faults_injected").and_then(Json::as_i64).unwrap_or(0),
        out.get("net_faults").and_then(Json::as_i64).unwrap_or(0),
        out.get("spine_plan").and_then(Json::as_str).unwrap_or("n/a"),
        optinic::sim::fmt_time(
            out.get("recovery_ns").and_then(Json::as_i64).unwrap_or(0) as u64
        ),
    );
    Ok(())
}
