"""Pure-jnp oracles for the L1 kernels.

Everything here is the *specification*: the Pallas kernel
(`hadamard.py`) and the Rust-native hot path (`rust/src/recovery/`)
are both validated against these functions.

The Hadamard transform used throughout is the **orthonormal**
Walsh–Hadamard transform (scaled by 1/sqrt(p)), which is its own
inverse — encode and decode are the same operation (§3.2a).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def fwht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Orthonormal fast Walsh–Hadamard transform over the last axis.

    x: [..., p] with p a power of two. Returns H @ x (same shape).
    """
    p = x.shape[-1]
    assert is_pow2(p), f"block size {p} must be a power of two"
    orig_shape = x.shape
    x = x.reshape(-1, p)
    h = 1
    while h < p:
        x = x.reshape(x.shape[0], -1, 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        x = x.reshape(x.shape[0], -1)
        h *= 2
    x = x * (1.0 / np.sqrt(p))
    return x.reshape(orig_shape)


def hadamard_blockwise_ref(x: jnp.ndarray, p: int) -> jnp.ndarray:
    """Block-wise Hadamard encode of a flat tensor (§3.2a).

    x: [n] flat; padded with zeros to a multiple of p, transformed in
    [B, p] blocks, and returned flat at the padded length.
    """
    n = x.shape[0]
    pad = (-n) % p
    xp = jnp.pad(x, (0, pad))
    blocks = xp.reshape(-1, p)
    return fwht_ref(blocks).reshape(-1)


def interleave_ref(encoded: jnp.ndarray, p: int, stride: int) -> jnp.ndarray:
    """Stride-based packet interleaving (§3.2b).

    `encoded`: flat, length a multiple of p, holding B blocks of p
    coefficients. Blocks are partitioned into groups of `stride`
    consecutive blocks; within a group, wire-packet j's slot m carries

        block  = g*S + (m mod S)
        coeff  = j*(p/S) + (m div S)

    so each packet holds p/S coefficients from each of S blocks: losing
    one packet erases only p/S coefficients per block, which the inverse
    transform disperses. B must be a multiple of `stride` (pad with zero
    blocks upstream if needed).
    """
    n = encoded.shape[0]
    assert n % p == 0
    nblocks = n // p
    assert p % stride == 0, "stride must divide p"
    assert nblocks % stride == 0, "block count must be a multiple of stride"
    s = stride
    per = p // s
    # [G, S, p] group-major blocks
    g = encoded.reshape(nblocks // s, s, p)
    # coeff index = j*per + t  →  reshape p axis to [S(j), per(t)]
    g = g.reshape(nblocks // s, s, s, per)  # [G, block_in_group(i), j, t]
    # wire packet j slot m: block i = m % S, t = m // S → [G, j, t, i]
    wire = jnp.transpose(g, (0, 2, 3, 1))  # [G, j, t, i]
    return wire.reshape(-1)


def deinterleave_ref(wire: jnp.ndarray, p: int, stride: int) -> jnp.ndarray:
    """Inverse of `interleave_ref`."""
    n = wire.shape[0]
    assert n % p == 0
    nblocks = n // p
    s = stride
    per = p // s
    w = wire.reshape(nblocks // s, s, per, s)  # [G, j, t, i]
    g = jnp.transpose(w, (0, 3, 1, 2))  # [G, i, j, t]
    return g.reshape(-1)


def simulate_packet_loss(
    wire: np.ndarray, p: int, drop_mask: np.ndarray
) -> np.ndarray:
    """Zero whole wire packets (p elements each) per the boolean mask."""
    w = wire.reshape(-1, p).copy()
    w[drop_mask] = 0.0
    return w.reshape(-1)
