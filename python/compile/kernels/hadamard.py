"""L1 Pallas kernel: block-wise fast Walsh–Hadamard transform.

The paper's recovery hot-spot (§3.2) is a CUDA warp-butterfly Hadamard
from HazyResearch. TPU adaptation (DESIGN.md §Hardware-Adaptation):
the FWHT is memory-bound VPU work, so the kernel tiles **rows of
blocks into VMEM** via BlockSpec — each program instance owns a
`(TILE_B, p)` tile, runs the log2(p) butterfly stages as in-register
vector ops, and writes back. The HBM↔VMEM schedule CUDA expressed with
threadblocks is the BlockSpec index map here.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated against `ref.fwht_ref` and the
real-TPU performance is *estimated* from the VMEM footprint
(EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import is_pow2

# VMEM budget per program instance: a (TILE_B, p) f32 tile must fit
# comfortably (≤ ~2 MiB leaves room for double-buffering on real TPUs).
VMEM_TILE_BYTES = 2 * 1024 * 1024


def tile_rows(p: int) -> int:
    """Rows per VMEM tile for block size p."""
    rows = max(1, VMEM_TILE_BYTES // (4 * p))
    # keep it a power of two for clean grids
    return 1 << (rows.bit_length() - 1)


def _fwht_kernel(x_ref, o_ref, *, p: int):
    """One VMEM tile: [TILE_B, p] → orthonormal FWHT along the last axis.

    The butterfly stages are unrolled at trace time (p is static);
    each stage is a reshape + add/sub — pure VPU work, no MXU.
    """
    x = x_ref[...]
    rows = x.shape[0]
    h = 1
    while h < p:
        x = x.reshape(rows, p // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        x = x.reshape(rows, p)
        h *= 2
    o_ref[...] = x * (1.0 / np.sqrt(p)).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("p",))
def hadamard_blocks(x: jnp.ndarray, p: int) -> jnp.ndarray:
    """Blockwise orthonormal FWHT of x: [B, p] → [B, p] via Pallas.

    B must be a multiple of the tile row count (pad upstream); p a power
    of two. Self-inverse: hadamard_blocks(hadamard_blocks(x)) == x.
    """
    assert is_pow2(p), f"p={p} must be a power of two"
    bsz, pp = x.shape
    assert pp == p
    tb = min(tile_rows(p), bsz)
    assert bsz % tb == 0, f"rows {bsz} not a multiple of tile {tb}"
    grid = (bsz // tb,)
    return pl.pallas_call(
        functools.partial(_fwht_kernel, p=p),
        out_shape=jax.ShapeDtypeStruct((bsz, p), x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((tb, p), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tb, p), lambda i: (i, 0)),
        interpret=True,
    )(x)


def hadamard_flat(x: jnp.ndarray, p: int) -> jnp.ndarray:
    """Flat-tensor convenience wrapper: pad to a block multiple, encode,
    return flat (padded length). Used by the L2 model's gradient path."""
    n = x.shape[0]
    pad = (-n) % p
    xp = jnp.pad(x, (0, pad))
    blocks = xp.reshape(-1, p)
    # row-pad so the Pallas grid divides evenly
    tb = min(tile_rows(p), blocks.shape[0])
    row_pad = (-blocks.shape[0]) % max(tb, 1)
    if row_pad:
        blocks = jnp.pad(blocks, ((0, row_pad), (0, 0)))
    out = hadamard_blocks(blocks, p)
    if row_pad:
        out = out[:-row_pad]
    return out.reshape(-1)


def vmem_report(p: int) -> dict:
    """Static VMEM/roofline estimate for the kernel at block size p
    (real-TPU perf is estimated, not measured — see module docstring)."""
    tb = tile_rows(p)
    tile_bytes = tb * p * 4
    stages = int(np.log2(p))
    # bytes moved per element: 1 read + 1 write of the tile (stages are
    # in-register); flops: 1 add/sub per element per stage
    return {
        "block_p": p,
        "tile_rows": tb,
        "tile_bytes": tile_bytes,
        "vmem_utilization": tile_bytes / VMEM_TILE_BYTES,
        "stages": stages,
        "flops_per_byte": stages / 8.0,  # adds per byte moved
        # TPU VPU roofline crossover sits around ~4 vector-ops/byte; every
        # practical block size is well below it → HBM-bandwidth bound
        "memory_bound": stages / 8.0 < 4.0,
    }
