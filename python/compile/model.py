"""L2: transformer language model with a *flat* parameter vector.

The whole model state lives in one f32[P] vector so the Rust
coordinator can treat parameters, gradients, and optimizer state as
opaque flat buffers — exactly what flows through the simulated
collectives. `fwd_bwd` returns (loss, grads[P]); `apply` is SGD with
momentum over flat vectors; `infer` returns next-token logits.

The gradient path can optionally route through the L1 Pallas Hadamard
kernel (`encode_grads`) so the entire §3.2 encode → (lossy network) →
decode pipeline lowers into the same HLO world.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import hadamard


@dataclass(frozen=True)
class ModelCfg:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# The model tiers used by the experiments (paper: Llama-3.2-1B, Phi-1,
# DeepSeek-R1-1.5B → three sizes of the same architecture on synthetic
# data; see DESIGN.md §2 substitutions).
CONFIGS: dict[str, ModelCfg] = {
    "tiny": ModelCfg("tiny", vocab=256, d_model=64, n_layers=2, n_heads=2,
                     d_ff=128, seq_len=32, batch=8),
    "small": ModelCfg("small", vocab=512, d_model=128, n_layers=4, n_heads=4,
                      d_ff=256, seq_len=64, batch=8),
    "medium": ModelCfg("medium", vocab=1024, d_model=256, n_layers=6,
                       n_heads=8, d_ff=512, seq_len=64, batch=8),
    "large": ModelCfg("large", vocab=4096, d_model=512, n_layers=8,
                      n_heads=8, d_ff=2048, seq_len=128, batch=4),
    # ~100M-parameter configuration for the end-to-end driver
    "xl": ModelCfg("xl", vocab=16384, d_model=768, n_layers=12, n_heads=12,
                   d_ff=3072, seq_len=256, batch=2),
}


# ---------------------------------------------------------------------------
# flat-parameter layout
# ---------------------------------------------------------------------------

def param_shapes(cfg: ModelCfg) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) layout of the flat parameter vector."""
    d, v, f, l = cfg.d_model, cfg.vocab, cfg.d_ff, cfg.n_layers
    shapes: list[tuple[str, tuple[int, ...]]] = [("embed", (v, d))]
    for i in range(l):
        shapes += [
            (f"l{i}.ln1", (d,)),
            (f"l{i}.wq", (d, d)),
            (f"l{i}.wk", (d, d)),
            (f"l{i}.wv", (d, d)),
            (f"l{i}.wo", (d, d)),
            (f"l{i}.ln2", (d,)),
            (f"l{i}.w1", (d, f)),
            (f"l{i}.w2", (f, d)),
        ]
    shapes += [("ln_f", (d,)), ("head", (d, v))]
    return shapes


def param_count(cfg: ModelCfg) -> int:
    return sum(int(np.prod(s)) for _, s in param_shapes(cfg))


def unflatten(cfg: ModelCfg, flat: jnp.ndarray) -> dict[str, jnp.ndarray]:
    out = {}
    off = 0
    for name, shape in param_shapes(cfg):
        n = int(np.prod(shape))
        out[name] = flat[off:off + n].reshape(shape)
        off += n
    return out


def init_params(cfg: ModelCfg, seed: int = 0) -> jnp.ndarray:
    """Flat parameter init (scaled normal; LN gains at 1)."""
    rng = np.random.default_rng(seed)
    parts = []
    for name, shape in param_shapes(cfg):
        n = int(np.prod(shape))
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            parts.append(np.ones(n, np.float32))
        elif name == "embed":
            parts.append(rng.normal(0, 0.02, n).astype(np.float32))
        else:
            fan_in = shape[0]
            parts.append(
                rng.normal(0, 1.0 / np.sqrt(fan_in), n).astype(np.float32))
    return jnp.asarray(np.concatenate(parts))


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------

def _layernorm(x, g):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g


def _attention(cfg: ModelCfg, x, wq, wk, wv, wo):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ wq).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    att = q @ k.transpose(0, 1, 3, 2) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return y @ wo


def forward(cfg: ModelCfg, flat: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: int32 [B, S] → logits [B, S, V]."""
    p = unflatten(cfg, flat)
    x = p["embed"][tokens]
    # sinusoidal position encoding (no learned positions → fewer params)
    s, d = tokens.shape[1], cfg.d_model
    pos = np.arange(s)[:, None] / (10000 ** (np.arange(0, d, 2) / d))[None, :]
    pe = np.zeros((s, d), np.float32)
    pe[:, 0::2] = np.sin(pos)
    pe[:, 1::2] = np.cos(pos)
    x = x + jnp.asarray(pe)
    for i in range(cfg.n_layers):
        x = x + _attention(cfg, _layernorm(x, p[f"l{i}.ln1"]),
                           p[f"l{i}.wq"], p[f"l{i}.wk"],
                           p[f"l{i}.wv"], p[f"l{i}.wo"])
        hdn = _layernorm(x, p[f"l{i}.ln2"])
        x = x + jax.nn.gelu(hdn @ p[f"l{i}.w1"]) @ p[f"l{i}.w2"]
    x = _layernorm(x, p["ln_f"])
    return x @ p["head"]


def loss_fn(cfg: ModelCfg, flat: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross entropy. tokens: int32 [B, S+1]."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, flat, inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


def fwd_bwd(cfg: ModelCfg, flat: jnp.ndarray, tokens: jnp.ndarray):
    """(loss, grads[P]) — the per-worker compute step."""
    loss, grads = jax.value_and_grad(lambda f: loss_fn(cfg, f, tokens))(flat)
    return loss, grads


def apply_grads(flat, grads, mom, lr, mu=0.9):
    """SGD with momentum over flat vectors → (params', momentum')."""
    mom2 = mu * mom + grads
    return flat - lr * mom2, mom2


def infer_logits(cfg: ModelCfg, flat: jnp.ndarray, tokens: jnp.ndarray):
    """Last-position logits [B, V] (decode step)."""
    return forward(cfg, flat, tokens)[:, -1, :]


def accuracy(cfg: ModelCfg, flat: jnp.ndarray, tokens: jnp.ndarray):
    """Next-token top-1 accuracy over a batch of sequences [B, S+1]."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, flat, inp)
    pred = jnp.argmax(logits, axis=-1)
    return (pred == tgt).mean()


# ---------------------------------------------------------------------------
# gradient encode/decode through the L1 Pallas kernel (§3.2 pipeline)
# ---------------------------------------------------------------------------

def encode_grads(grads: jnp.ndarray, p: int) -> jnp.ndarray:
    """Block-wise Hadamard encode of a flat gradient (pads to p)."""
    return hadamard.hadamard_flat(grads, p)


def decode_grads(encoded: jnp.ndarray, p: int, n: int) -> jnp.ndarray:
    """Inverse transform (self-inverse) and trim padding to n elements."""
    return hadamard.hadamard_flat(encoded, p)[:n]
