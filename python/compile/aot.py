"""AOT pipeline: lower every L2/L1 computation to HLO **text** and write
`artifacts/` + `manifest.json` for the Rust runtime.

HLO text — not `.serialize()` — is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts [--models tiny,small,medium]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import hadamard

# Hadamard kernel shapes exported for the Rust hot path / Table 3.
HADAMARD_SHAPES = [
    # (rows, block p)
    (64, 256),
    (64, 1024),
    (16, 4096),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_and_write(fn, args, path: str) -> dict:
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    def spec(a):
        return {"shape": list(a.shape), "dtype": str(a.dtype)}
    return {
        "file": os.path.basename(path),
        "inputs": [spec(a) for a in args],
        "hlo_bytes": len(text),
    }


def build_model_artifacts(cfg: M.ModelCfg, outdir: str) -> dict:
    pcount = M.param_count(cfg)
    flat = jax.ShapeDtypeStruct((pcount,), jnp.float32)
    tokens_train = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    tokens_infer = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)

    entries = {}
    entries["fwd_bwd"] = lower_and_write(
        lambda f, t: M.fwd_bwd(cfg, f, t),
        (flat, tokens_train),
        os.path.join(outdir, f"{cfg.name}_fwd_bwd.hlo.txt"),
    )
    entries["apply"] = lower_and_write(
        lambda f, g, m, l: M.apply_grads(f, g, m, l),
        (flat, flat, flat, lr),
        os.path.join(outdir, f"{cfg.name}_apply.hlo.txt"),
    )
    entries["infer"] = lower_and_write(
        lambda f, t: (M.infer_logits(cfg, f, t),),
        (flat, tokens_infer),
        os.path.join(outdir, f"{cfg.name}_infer.hlo.txt"),
    )
    entries["accuracy"] = lower_and_write(
        lambda f, t: (M.accuracy(cfg, f, t),),
        (flat, tokens_train),
        os.path.join(outdir, f"{cfg.name}_accuracy.hlo.txt"),
    )
    # initial parameters as raw f32 little-endian (deterministic seed)
    params = M.init_params(cfg, seed=42)
    init_path = os.path.join(outdir, f"{cfg.name}_init.f32")
    import numpy as np
    np.asarray(params, dtype=np.float32).tofile(init_path)

    return {
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "batch": cfg.batch,
        },
        "param_count": pcount,
        "init_file": os.path.basename(init_path),
        "artifacts": entries,
    }


def build_hadamard_artifacts(outdir: str) -> dict:
    out = {}
    for rows, p in HADAMARD_SHAPES:
        x = jax.ShapeDtypeStruct((rows, p), jnp.float32)
        entry = lower_and_write(
            lambda a, p=p: (hadamard.hadamard_blocks(a, p),),
            (x,),
            os.path.join(outdir, f"hadamard_{rows}x{p}.hlo.txt"),
        )
        entry["vmem"] = hadamard.vmem_report(p)
        out[f"{rows}x{p}"] = entry
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models",
        default="tiny,small,medium",
        help="comma-separated model tiers (tiny,small,medium,large,xl)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest: dict = {"format": "hlo-text", "models": {}, "hadamard": {}}
    manifest["hadamard"] = build_hadamard_artifacts(args.out)
    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        cfg = M.CONFIGS[name]
        print(f"lowering model '{name}' ({M.param_count(cfg):,} params)...")
        manifest["models"][name] = build_model_artifacts(cfg, args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
