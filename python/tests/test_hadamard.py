"""L1 kernel correctness: Pallas FWHT vs the pure-jnp oracle, plus the
mathematical properties the recovery pipeline (§3.2) depends on."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hadamard, ref


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(0, 1, shape).astype(np.float32)


# ---------------------------------------------------------------------------
# oracle self-checks
# ---------------------------------------------------------------------------

class TestRef:
    def test_matches_dense_hadamard_matrix(self):
        # H_2 = [[1,1],[1,-1]]/sqrt(2); build H_8 by kron and compare
        h = np.array([[1.0, 1.0], [1.0, -1.0]])
        H = h
        for _ in range(2):
            H = np.kron(H, h)
        H = H / np.sqrt(8)
        x = rand((3, 8))
        want = x @ H.T
        got = np.asarray(ref.fwht_ref(jnp.asarray(x)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_self_inverse(self):
        x = rand((4, 64), seed=1)
        y = ref.fwht_ref(ref.fwht_ref(jnp.asarray(x)))
        np.testing.assert_allclose(np.asarray(y), x, rtol=1e-4, atol=1e-5)

    def test_preserves_norm(self):
        x = rand((2, 128), seed=2)
        y = np.asarray(ref.fwht_ref(jnp.asarray(x)))
        np.testing.assert_allclose(
            np.linalg.norm(y, axis=-1), np.linalg.norm(x, axis=-1), rtol=1e-5
        )

    def test_rejects_non_pow2(self):
        with pytest.raises(AssertionError):
            ref.fwht_ref(jnp.zeros((2, 12)))

    def test_blockwise_pads(self):
        x = jnp.asarray(rand((100,), seed=3))
        y = ref.hadamard_blockwise_ref(x, 64)
        assert y.shape[0] == 128  # padded to 2 blocks
        # decode and trim recovers
        back = ref.hadamard_blockwise_ref(y, 64)[:100]
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle
# ---------------------------------------------------------------------------

class TestPallasKernel:
    @pytest.mark.parametrize("p", [2, 16, 64, 256, 1024])
    @pytest.mark.parametrize("rows", [1, 4, 64])
    def test_matches_ref(self, p, rows):
        tb = min(hadamard.tile_rows(p), rows)
        if rows % tb != 0:
            pytest.skip("rows not tile-aligned (wrapper pads)")
        x = jnp.asarray(rand((rows, p), seed=p + rows))
        got = hadamard.hadamard_blocks(x, p)
        want = ref.fwht_ref(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        logp=st.integers(min_value=1, max_value=9),
        rows=st.integers(min_value=1, max_value=9),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_shapes_match_ref(self, logp, rows, seed):
        """Property sweep over block sizes/rows: kernel ≡ oracle."""
        p = 1 << logp
        n = rows * p - (p // 3)  # deliberately unaligned flat length
        x = jnp.asarray(rand((max(n, 1),), seed=seed))
        got = hadamard.hadamard_flat(x, p)
        want = ref.hadamard_blockwise_ref(x, p)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=1e-4)

    def test_flat_self_inverse(self):
        x = jnp.asarray(rand((1000,), seed=9))
        y = hadamard.hadamard_flat(hadamard.hadamard_flat(x, 256), 256)[:1000]
        np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                                   rtol=1e-4, atol=1e-5)

    def test_bf16_dtype(self):
        x = jnp.asarray(rand((8, 64), seed=4)).astype(jnp.bfloat16)
        got = hadamard.hadamard_blocks(x, 64)
        want = ref.fwht_ref(x.astype(jnp.float32))
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32), np.asarray(want),
            rtol=5e-2, atol=5e-2,
        )

    def test_vmem_report_sane(self):
        r = hadamard.vmem_report(1024)
        assert r["tile_bytes"] <= hadamard.VMEM_TILE_BYTES
        assert r["stages"] == 10
        assert r["memory_bound"]


# ---------------------------------------------------------------------------
# stride interleaving (§3.2b)
# ---------------------------------------------------------------------------

class TestStride:
    @pytest.mark.parametrize("p,s,blocks", [(8, 1, 4), (8, 2, 4), (8, 8, 8),
                                            (64, 16, 16), (256, 256, 256)])
    def test_roundtrip(self, p, s, blocks):
        x = jnp.asarray(rand((blocks * p,), seed=s))
        w = ref.interleave_ref(x, p, s)
        back = ref.deinterleave_ref(w, p, s)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))

    @pytest.mark.parametrize("s", [1, 2, 4, 8])
    def test_packet_loss_spreads_across_blocks(self, s):
        """Losing one wire packet erases exactly p/s coefficients in each
        of s blocks — the §3.2b dispersion property."""
        p, blocks = 8, 8
        x = np.arange(blocks * p, dtype=np.float32) + 1.0
        w = np.asarray(ref.interleave_ref(jnp.asarray(x), p, s))
        lost = w.reshape(-1, p).copy()
        lost[0] = 0.0  # drop wire packet 0
        back = np.asarray(ref.deinterleave_ref(jnp.asarray(lost.reshape(-1)), p, s))
        zeros_per_block = (back.reshape(blocks, p) == 0).sum(axis=1)
        affected = zeros_per_block > 0
        assert affected.sum() == s, f"{zeros_per_block}"
        assert all(zeros_per_block[affected] == p // s)

    def test_golden_vector(self):
        """Golden permutation pinned against the Rust implementation
        (rust/src/recovery/stride.rs has the identical table)."""
        p, s = 4, 2
        x = jnp.arange(8, dtype=jnp.float32)  # 2 blocks of 4
        w = np.asarray(ref.interleave_ref(x, p, s))
        # wire packet j slot m → block m%2, coeff j*2 + m//2
        # j=0: [b0c0, b1c0, b0c1, b1c1] = [0, 4, 1, 5]
        # j=1: [b0c2, b1c2, b0c3, b1c3] = [2, 6, 3, 7]
        np.testing.assert_array_equal(w, [0, 4, 1, 5, 2, 6, 3, 7])


class TestRecoveryPipeline:
    @pytest.mark.parametrize("drop_rate", [0.02, 0.05])
    def test_stride_disperses_worst_element_error(self, drop_rate):
        """The §3.2b property in its robust form: for orthonormal transforms
        the *expected* MSE under uniform drops is Parseval-invariant, so the
        stride's benefit is dispersion — under identical drop patterns, the
        worst single-element error with maximal stride must be far below the
        no-stride (whole-block-loss) case."""
        rng = np.random.default_rng(7)
        p, blocks = 64, 32
        x = rng.normal(0, 1, blocks * p).astype(np.float32)

        def worst_err(stride, mask):
            enc = np.asarray(ref.hadamard_blockwise_ref(jnp.asarray(x), p))
            wire = np.asarray(ref.interleave_ref(jnp.asarray(enc), p, stride))
            lost = ref.simulate_packet_loss(wire, p, mask)
            enc2 = np.asarray(ref.deinterleave_ref(jnp.asarray(lost), p, stride))
            dec = np.asarray(ref.hadamard_blockwise_ref(jnp.asarray(enc2), p))
            return float(np.abs(dec - x).max())

        worst_block, worst_stride = [], []
        for _ in range(6):
            mask = rng.random(blocks) < drop_rate
            if not mask.any():
                mask[0] = True
            worst_block.append(worst_err(1, mask))
            # maximal usable stride: must divide p and the block count
            worst_stride.append(worst_err(min(p, blocks), mask))
        # dropping a whole encoded block destroys its largest element;
        # maximal stride spreads the same loss thinly
        assert np.mean(worst_stride) < 0.7 * np.mean(worst_block), (
            worst_stride,
            worst_block,
        )
