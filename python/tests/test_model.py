"""L2 model checks: shapes, gradient flow, learnability on a synthetic
Markov corpus, and the flat-parameter layout the Rust runtime relies on."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


CFG = M.CONFIGS["tiny"]


def synth_batch(cfg, seed=0, batch=None):
    """Zipf–Markov synthetic token stream (mirrors rust/src/data)."""
    rng = np.random.default_rng(seed)
    b = batch or cfg.batch
    toks = np.zeros((b, cfg.seq_len + 1), np.int32)
    # simple deterministic bigram structure: next = (3*cur + noise) % vocab
    toks[:, 0] = rng.integers(0, cfg.vocab, b)
    for t in range(1, cfg.seq_len + 1):
        noise = rng.integers(0, 4, b)
        toks[:, t] = (3 * toks[:, t - 1] + noise) % cfg.vocab
    return jnp.asarray(toks)


class TestLayout:
    def test_param_count_matches_shapes(self):
        shapes = M.param_shapes(CFG)
        total = sum(int(np.prod(s)) for _, s in shapes)
        assert total == M.param_count(CFG)
        flat = M.init_params(CFG)
        assert flat.shape == (total,)

    def test_unflatten_covers_everything(self):
        flat = jnp.arange(M.param_count(CFG), dtype=jnp.float32)
        parts = M.unflatten(CFG, flat)
        seen = sum(int(np.prod(v.shape)) for v in parts.values())
        assert seen == M.param_count(CFG)
        # first parameter is the embedding, starting at offset 0
        assert float(parts["embed"].reshape(-1)[0]) == 0.0

    def test_config_tiers_grow(self):
        sizes = [M.param_count(M.CONFIGS[n])
                 for n in ["tiny", "small", "medium", "large", "xl"]]
        assert all(a < b for a, b in zip(sizes, sizes[1:]))
        # the end-to-end tier is ~100M params
        assert 70e6 < sizes[-1] < 160e6, sizes[-1]


class TestForward:
    def test_logit_shapes(self):
        flat = M.init_params(CFG)
        toks = synth_batch(CFG)[:, :-1]
        logits = M.forward(CFG, flat, toks)
        assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)

    def test_causality(self):
        """Changing a future token must not affect earlier logits."""
        flat = M.init_params(CFG)
        toks = np.asarray(synth_batch(CFG, seed=1)[:, :-1])
        logits1 = M.forward(CFG, flat, jnp.asarray(toks))
        toks2 = toks.copy()
        toks2[:, -1] = (toks2[:, -1] + 1) % CFG.vocab
        logits2 = M.forward(CFG, flat, jnp.asarray(toks2))
        np.testing.assert_allclose(
            np.asarray(logits1[:, :-1]), np.asarray(logits2[:, :-1]),
            rtol=1e-5, atol=1e-5,
        )

    def test_loss_near_uniform_at_init(self):
        flat = M.init_params(CFG)
        loss = M.loss_fn(CFG, flat, synth_batch(CFG))
        assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


class TestTraining:
    def test_grads_shape_and_finite(self):
        flat = M.init_params(CFG)
        loss, grads = M.fwd_bwd(CFG, flat, synth_batch(CFG))
        assert grads.shape == flat.shape
        assert np.isfinite(float(loss))
        assert np.isfinite(np.asarray(grads)).all()
        assert float(jnp.abs(grads).max()) > 0

    def test_loss_decreases(self):
        """A few SGD steps on the structured corpus must reduce loss."""
        cfg = CFG
        flat = M.init_params(cfg)
        mom = jnp.zeros_like(flat)
        step = jax.jit(lambda f, m, t: (
            M.fwd_bwd(cfg, f, t)[0],
            *M.apply_grads(f, M.fwd_bwd(cfg, f, t)[1], m, jnp.float32(0.05)),
        ))
        losses = []
        for i in range(12):
            loss, flat, mom = step(flat, mom, synth_batch(cfg, seed=100 + i))
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.3, losses

    def test_apply_grads_momentum(self):
        f = jnp.ones(4)
        g = jnp.full(4, 2.0)
        m = jnp.zeros(4)
        f1, m1 = M.apply_grads(f, g, m, jnp.float32(0.1))
        np.testing.assert_allclose(np.asarray(m1), 2.0)
        np.testing.assert_allclose(np.asarray(f1), 1.0 - 0.2)
        f2, m2 = M.apply_grads(f1, g, m1, jnp.float32(0.1))
        np.testing.assert_allclose(np.asarray(m2), 0.9 * 2.0 + 2.0)


class TestInference:
    def test_infer_logits_shape(self):
        flat = M.init_params(CFG)
        toks = synth_batch(CFG)[:, :-1]
        out = M.infer_logits(CFG, flat, toks)
        assert out.shape == (CFG.batch, CFG.vocab)

    def test_accuracy_bounds(self):
        flat = M.init_params(CFG)
        acc = float(M.accuracy(CFG, flat, synth_batch(CFG)))
        assert 0.0 <= acc <= 1.0


class TestGradEncoding:
    def test_encode_decode_roundtrip(self):
        flat = M.init_params(CFG)
        _, grads = M.fwd_bwd(CFG, flat, synth_batch(CFG))
        enc = M.encode_grads(grads, 256)
        dec = M.decode_grads(enc, 256, grads.shape[0])
        np.testing.assert_allclose(np.asarray(dec), np.asarray(grads),
                                   rtol=1e-3, atol=1e-5)

    def test_encoding_is_linear(self):
        """Linearity (§3.2a): encoded tensors can be reduced without
        decoding — encode(a+b) == encode(a) + encode(b)."""
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(0, 1, 1000).astype(np.float32))
        b = jnp.asarray(rng.normal(0, 1, 1000).astype(np.float32))
        lhs = M.encode_grads(a + b, 256)
        rhs = M.encode_grads(a, 256) + M.encode_grads(b, 256)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-4, atol=1e-5)
